//! Data-parallel deterministic training over the pure-Rust executors.
//!
//! The paper's headline evaluations (§VI) train LeNets/ResNets with
//! approximate multipliers at scales that are only tractable multi-worker,
//! and simulated-multiplier studies need *repeatable* loss curves so an
//! accuracy delta can be attributed to the multiplier rather than to
//! nondeterminism. This module applies the crate-wide accumulation
//! contract (one running FP32 accumulator, fixed order — the trick that
//! made the threaded GEMM bit-identical to the scalar oracle) one level
//! up, to gradient reduction, so the *entire loss curve* is bit-identical
//! for any worker count, for native, direct, and LUT multipliers alike.
//!
//! ## Why it is deterministic
//!
//! FP32 addition does not associate, so any scheme whose reduction order
//! depends on how many workers ran (or which worker finished first) will
//! drift between worker counts. Three decisions remove every such
//! dependence:
//!
//! 1. **The numerical decomposition is fixed by the shard size, not the
//!    worker count.** [`shard_ranges`] cuts a minibatch into "leaves" of
//!    `DpConfig::shard` samples (ragged last leaf). Each leaf's gradient
//!    is a pure function of (parameters, leaf samples) — computed by the
//!    models' `grad_step(&self, ..)` with the loss gradient pre-scaled by
//!    the *effective* batch size. Workers merely claim leaves
//!    ([`worker_shares`]); N changes who computes a leaf, never what is
//!    computed.
//! 2. **Leaf gradients meet in a fixed-order binary tree.**
//!    [`tree_reduce`] folds gap-doubling over the leaf list
//!    (`leaves[i] += leaves[i+gap]`, gap = 1, 2, 4, …): the tree's shape
//!    is a function of the leaf *index* only. The fold is parallelized
//!    over disjoint **element ranges** — every element's additions happen
//!    in tree order inside one thread — so thread count never touches the
//!    bits, only the wall clock.
//! 3. **Metrics reduce exactly.** Leaf losses are kept as FP32 *sums*
//!    (reduced through the same tree, divided once at the end) and
//!    accuracies as integer correct-counts, so the reported curve carries
//!    no per-shard averaging error.
//!
//! Gradient accumulation rides the same machinery: `k` micro-batches are
//! cut into one concatenated leaf list and reduced through one tree, so
//! when leaf boundaries align (`shard` divides the micro-batch size) the
//! accumulated step is **bitwise equal** to the monolithic large-batch
//! step for the batchnorm-free models. `CpuResnet` normalizes over each
//! `grad_step` call's rows (shard-local batch statistics), so its
//! *decomposition* is part of its numerics: different shard sizes are
//! legitimately different BN models — but any fixed decomposition is
//! still bit-identical across worker counts, which is the invariant this
//! module guarantees. The `rust/tests/data_parallel.rs` suite enforces
//! all of it.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, bail, Result};

use super::backend::{CpuModel, MulSpec};
use super::pruning::{apply_mask, Mask};
use crate::data::{Batcher, Dataset, EvalBatcher};
use crate::nn::checkpoint::Checkpoint;
use crate::nn::metrics::correct_from_logits;
use crate::tensor::Tensor;
use crate::util::threads;

// ---------------------------------------------------------------------------
// Reduction primitives (pure, unit-testable)
// ---------------------------------------------------------------------------

/// Cut `n` samples into fixed-size leaves: `[0, shard)`, `[shard, 2*shard)`,
/// …, with a ragged final leaf. The decomposition depends only on `(n,
/// shard)` — never on worker count — which is what pins the bits of the
/// whole data-parallel step. `shard` is clamped to at least 1.
pub fn shard_ranges(n: usize, shard: usize) -> Vec<(usize, usize)> {
    let shard = shard.max(1);
    let mut out = Vec::with_capacity(n.div_ceil(shard));
    let mut s = 0;
    while s < n {
        let e = (s + shard).min(n);
        out.push((s, e));
        s = e;
    }
    out
}

/// Split `tasks` leaf indices into at most `workers` contiguous non-empty
/// shares, share `w` = `[start, end)`. Balanced like the pool's chunking:
/// `ceil(tasks/workers)` leaves per share. Degenerate cases fold away
/// cleanly: more workers than leaves yields one share per leaf (extra
/// workers idle), one worker yields a single share holding every leaf.
pub fn worker_shares(tasks: usize, workers: usize) -> Vec<(usize, usize)> {
    if tasks == 0 {
        return Vec::new();
    }
    let per = tasks.div_ceil(workers.max(1));
    let mut out = Vec::with_capacity(tasks.div_ceil(per));
    let mut s = 0;
    while s < tasks {
        let e = (s + per).min(tasks);
        out.push((s, e));
        s = e;
    }
    out
}

/// Fixed-order binary tree sum over equal-length leaf vectors, in place;
/// returns what was `leaves[0]` holding the reduction. Gap-doubling:
/// round `g` folds `leaves[i] += leaves[i+g]` for `i = 0, 2g, 4g, …` —
/// the tree shape is a function of the leaf index only. One running FP32
/// accumulator per element; parallelism (up to `workers` lanes on the
/// global pool) is over disjoint *element ranges*, so every element sees
/// its additions in tree order regardless of thread count or schedule.
pub fn tree_reduce(mut leaves: Vec<Vec<f32>>, workers: usize) -> Vec<f32> {
    let count = leaves.len();
    assert!(count > 0, "tree_reduce needs at least one leaf");
    let n = leaves[0].len();
    assert!(leaves.iter().all(|l| l.len() == n), "tree_reduce leaf length mismatch");
    if count > 1 && n > 0 {
        let ptrs: Vec<threads::SendMutPtr> =
            leaves.iter_mut().map(|l| threads::SendMutPtr(l.as_mut_ptr())).collect();
        let ptrs = &ptrs;
        threads::parallel_ranges(n, workers.max(1), |_, s, e| {
            let mut gap = 1;
            while gap < count {
                let mut i = 0;
                while i + gap < count {
                    // SAFETY: distinct leaves (dst != src) and disjoint
                    // element ranges per chunk; the Vecs outlive the call.
                    unsafe {
                        let (dst, src) = (ptrs[i].0, ptrs[i + gap].0);
                        for k in s..e {
                            *dst.add(k) += *src.add(k);
                        }
                    }
                    i += 2 * gap;
                }
                gap *= 2;
            }
        });
    }
    leaves.swap_remove(0)
}

/// Scalar twin of [`tree_reduce`] (used for per-leaf loss sums): same
/// gap-doubling shape, so scalar metrics reduce through the *same* tree
/// as the gradients.
pub fn tree_reduce_scalar(vals: &[f32]) -> f32 {
    assert!(!vals.is_empty(), "tree_reduce_scalar needs at least one value");
    let mut v = vals.to_vec();
    let count = v.len();
    let mut gap = 1;
    while gap < count {
        let mut i = 0;
        while i + gap < count {
            v[i] += v[i + gap];
            i += 2 * gap;
        }
        gap *= 2;
    }
    v[0]
}

// ---------------------------------------------------------------------------
// Replicas and the trainer
// ---------------------------------------------------------------------------

/// One training replica: an owned model + an owned multiplier. All
/// replicas of a trainer hold bit-identical parameters at every step
/// boundary (same init, same reduced gradient applied everywhere).
#[derive(Clone)]
pub struct TrainReplica {
    pub model: CpuModel,
    pub mul: MulSpec,
}

impl TrainReplica {
    /// Fresh replica for a model name (`lenet300` | `lenet5` |
    /// `resnet18|34|50`), deterministically initialized from `seed`.
    pub fn for_model(model: &str, mul: MulSpec, seed: u64) -> Result<TrainReplica> {
        Ok(TrainReplica { model: CpuModel::for_name(model, seed)?, mul })
    }

    /// `n` bit-identical replicas (PR 5's serving-lane idiom). Note
    /// `MulSpec::clone` resolves `direct:` multipliers through the
    /// registry — hand-built unregistered multipliers must construct
    /// each replica explicitly instead.
    pub fn replicas(&self, n: usize) -> Vec<TrainReplica> {
        (0..n).map(|_| self.clone()).collect()
    }
}

/// Data-parallel training configuration.
#[derive(Clone, Copy, Debug)]
pub struct DpConfig {
    /// Worker lanes (= replicas). Changes throughput only, never bits.
    pub workers: usize,
    /// Samples per leaf shard. Part of the numerical decomposition:
    /// changing it is (for BN models) changing the model, so it is a
    /// config knob, not a worker-count derivative.
    pub shard: usize,
    /// Plain SGD learning rate.
    pub lr: f32,
}

/// Per-optimizer-step statistics; `loss`/`acc` are exact functions of the
/// tree-reduced sums, so the whole curve is bit-comparable across runs.
#[derive(Clone, Copy, Debug)]
pub struct DpStepStats {
    pub loss: f32,
    pub acc: f32,
    pub samples: usize,
    pub leaves: usize,
}

/// Deterministic data-parallel trainer: N replicas, fixed-shard minibatch
/// decomposition, fixed-order gradient reduction tree, plain SGD.
pub struct DpTrainer {
    replicas: Vec<TrainReplica>,
    cfg: DpConfig,
    mask: Option<Mask>,
}

impl DpTrainer {
    /// Build `cfg.workers` bit-identical replicas of `model` initialized
    /// from `seed`.
    pub fn new(model: &str, mul: MulSpec, cfg: DpConfig, seed: u64) -> Result<DpTrainer> {
        let base = TrainReplica::for_model(model, mul, seed)?;
        Self::from_replicas(base.replicas(cfg.workers.max(1)), cfg)
    }

    /// Wrap pre-built replicas (tests use this to inject custom
    /// multipliers or small models). `cfg.workers` must match.
    pub fn from_replicas(replicas: Vec<TrainReplica>, cfg: DpConfig) -> Result<DpTrainer> {
        if replicas.is_empty() {
            bail!("data-parallel trainer needs at least one replica");
        }
        if cfg.workers != replicas.len() {
            bail!("cfg.workers = {} but {} replicas were supplied", cfg.workers, replicas.len());
        }
        if cfg.shard == 0 {
            bail!("cfg.shard must be at least 1 sample per leaf");
        }
        if !cfg.lr.is_finite() {
            bail!("cfg.lr must be finite, got {}", cfg.lr);
        }
        let p0 = replicas[0].model.param_count();
        if replicas.iter().any(|r| r.model.param_count() != p0) {
            bail!("replicas disagree on parameter count");
        }
        Ok(DpTrainer { replicas, cfg, mask: None })
    }

    /// Install (or clear) a pruning mask over the *flat* parameter
    /// vector: while set, pruned entries are forced back to zero after
    /// every optimizer step, so sparse fine-tuning stays sparse and the
    /// zero-skipping GEMM drain keeps seeing dead panels. The mask rides
    /// the determinism contract for free — it is applied once to the
    /// post-reduction parameter vector and broadcast to every replica,
    /// after the point where all replicas are already bit-identical, so
    /// N-worker and 1-worker sparse training produce the same bits
    /// (enforced by `rust/tests/data_parallel.rs`).
    pub fn set_mask(&mut self, mask: Option<Mask>) -> Result<()> {
        if let Some(m) = &mask {
            let n = self.replicas[0].model.param_count();
            if m.keep.len() != n {
                bail!("mask covers {} params, model has {n}", m.keep.len());
            }
        }
        self.mask = mask;
        Ok(())
    }

    /// The installed flat-parameter mask, if any.
    pub fn mask(&self) -> Option<&Mask> {
        self.mask.as_ref()
    }

    pub fn config(&self) -> DpConfig {
        self.cfg
    }

    pub fn workers(&self) -> usize {
        self.replicas.len()
    }

    /// Human-readable identity for logs/records.
    pub fn describe(&self) -> String {
        format!(
            "dp:{}:workers={}:shard={}",
            self.replicas[0].mul.describe(),
            self.replicas.len(),
            self.cfg.shard
        )
    }

    /// Flat parameter snapshot (replica 0; all replicas are identical at
    /// step boundaries).
    pub fn flat_params(&self) -> Vec<f32> {
        self.replicas[0].model.flat_params()
    }

    /// Overwrite every replica's parameters from one flat vector.
    pub fn load_flat(&mut self, flat: &[f32]) {
        for r in &mut self.replicas {
            r.model.load_flat(flat);
        }
    }

    /// One optimizer step on one minibatch (`images` row-major
    /// `[n, ...input dims]`, one label per row).
    pub fn step(&mut self, images: &[f32], labels: &[u32]) -> Result<DpStepStats> {
        self.step_accum(&[(images, labels)])
    }

    /// One optimizer step accumulating `k` micro-batches: every
    /// micro-batch is cut into leaves, all leaves reduce through one
    /// fixed-order tree, and SGD applies once with the loss gradient
    /// scaled by the total sample count. With aligned leaf boundaries
    /// this is bitwise the monolithic concatenated-batch step (for the
    /// BN-free models; see the module docs for the resnet caveat).
    pub fn step_accum(&mut self, micros: &[(&[f32], &[u32])]) -> Result<DpStepStats> {
        let dims = self.replicas[0].model.input_dims();
        let elems: usize = dims.iter().product();
        if micros.is_empty() {
            bail!("step_accum needs at least one micro-batch");
        }
        for (mi, (images, labels)) in micros.iter().enumerate() {
            if labels.is_empty() {
                bail!("micro-batch {mi} is empty");
            }
            if images.len() != labels.len() * elems {
                bail!(
                    "micro-batch {mi}: {} image f32s for {} labels (model takes {} per sample)",
                    images.len(),
                    labels.len(),
                    elems
                );
            }
        }
        let total: usize = micros.iter().map(|(_, l)| l.len()).sum();

        // fixed decomposition: leaves are (micro index, sample range),
        // a function of the micro-batch sizes and cfg.shard only
        let mut leaves: Vec<(usize, usize, usize)> = Vec::new();
        for (mi, (_, labels)) in micros.iter().enumerate() {
            for (s, e) in shard_ranges(labels.len(), self.cfg.shard) {
                leaves.push((mi, s, e));
            }
        }
        let shares = worker_shares(leaves.len(), self.replicas.len());

        // fan-out: each share runs on its own replica; a leaf gradient is
        // a pure function of (params, leaf), so who runs it is irrelevant
        let slots: Vec<Mutex<Option<(f32, usize, Vec<f32>)>>> =
            leaves.iter().map(|_| Mutex::new(None)).collect();
        let replicas = &self.replicas;
        let leaves_ref = &leaves;
        let shares_ref = &shares;
        let slots_ref = &slots;
        let run = catch_unwind(AssertUnwindSafe(|| {
            threads::global().run_tasks(shares_ref.len(), |w| {
                let rep = &replicas[w];
                let mul = rep.mul.kernel();
                let (ls, le) = shares_ref[w];
                for li in ls..le {
                    let (mi, s, e) = leaves_ref[li];
                    let (images, labels) = micros[mi];
                    let mut shape = vec![e - s];
                    shape.extend_from_slice(&dims);
                    let x = Tensor::from_vec(&shape, images[s * elems..e * elems].to_vec());
                    let out = rep.model.grad_step(&mul, &x, &labels[s..e], total);
                    *slots_ref[li].lock().unwrap() = Some(out);
                }
            });
        }));
        if let Err(payload) = run {
            // fail-stop: no gradient was reduced and no parameter was
            // touched (grad_step is &self), so the trainer state is
            // exactly the pre-step state
            return Err(anyhow!(
                "data-parallel step failed: a replica panicked mid-step ({}); \
                 parameters are untouched",
                panic_msg(&payload)
            ));
        }

        // fan-in through the fixed-order tree
        let mut loss_leaves = Vec::with_capacity(leaves.len());
        let mut grad_leaves = Vec::with_capacity(leaves.len());
        let mut correct = 0usize;
        for slot in slots {
            let (loss_sum, c, grad) =
                slot.into_inner().unwrap().expect("every leaf completed without panicking");
            loss_leaves.push(loss_sum);
            correct += c;
            grad_leaves.push(grad);
        }
        let leaf_count = grad_leaves.len();
        let grad = tree_reduce(grad_leaves, self.replicas.len());
        let loss_sum = tree_reduce_scalar(&loss_leaves);

        // apply the one reduced gradient to every replica (they stay
        // bit-identical at step boundaries)
        for r in &mut self.replicas {
            r.model.apply_grads(&grad, self.cfg.lr);
        }
        // pruning mask: zero the pruned entries of the (now identical)
        // post-step parameters once and broadcast, keeping replicas
        // bit-identical at the step boundary
        if let Some(mask) = &self.mask {
            let mut flat = self.replicas[0].model.flat_params();
            apply_mask(&mut flat, mask);
            for r in &mut self.replicas {
                r.model.load_flat(&flat);
            }
        }
        // same `* (1/b)` head as the models' train_step, so a one-leaf DP
        // step reports bitwise the same loss/acc as a plain train_step
        let inv = 1.0 / total as f32;
        Ok(DpStepStats {
            loss: loss_sum * inv,
            acc: correct as f32 * inv,
            samples: total,
            leaves: leaf_count,
        })
    }

    /// Train `epochs` over `ds` with the deterministic [`Batcher`] stream,
    /// grouping `accum` consecutive minibatches into one optimizer step.
    /// Returns one [`DpStepStats`] per optimizer step — the loss curve
    /// the bit-identity gates compare.
    pub fn fit(
        &mut self,
        ds: &Dataset,
        epochs: usize,
        batch: usize,
        accum: usize,
        seed: u64,
    ) -> Result<Vec<DpStepStats>> {
        let accum = accum.max(1);
        let mut curve = Vec::new();
        for epoch in 0..epochs {
            let batches: Vec<(Vec<f32>, Vec<u32>)> =
                Batcher::new(ds, batch, seed, epoch as u64).collect();
            for group in batches.chunks(accum) {
                let micros: Vec<(&[f32], &[u32])> =
                    group.iter().map(|(i, l)| (i.as_slice(), l.as_slice())).collect();
                curve.push(self.step_accum(&micros)?);
            }
        }
        Ok(curve)
    }

    /// Test-set accuracy of the shared parameters (replica 0 forward over
    /// an in-order [`EvalBatcher`]; exact integer correct-counts).
    pub fn evaluate(&self, ds: &Dataset, batch: usize) -> Result<f32> {
        if ds.n == 0 {
            bail!("cannot evaluate on an empty dataset");
        }
        let rep = &self.replicas[0];
        let dims = rep.model.input_dims();
        let elems: usize = dims.iter().product();
        if ds.image_len() != elems {
            bail!("dataset rows have {} f32s, model takes {elems}", ds.image_len());
        }
        let classes = rep.model.classes();
        let mul = rep.mul.kernel();
        let mut correct = 0usize;
        for (images, labels) in EvalBatcher::new(ds, batch) {
            let mut shape = vec![batch];
            shape.extend_from_slice(&dims);
            let logits = rep.model.forward(&mul, &Tensor::from_vec(&shape, images));
            correct += correct_from_logits(&logits.data[..labels.len() * classes], labels, classes);
        }
        Ok(correct as f32 / ds.n as f32)
    }

    /// Save the flat parameter vector split across up to `shards`
    /// checkpoint files (`dp-shard-NNN.ckpt`, each holding one tensor
    /// named `flat/<offset>`). Shard count is a storage choice only: any
    /// sharding reassembles to the same vector.
    pub fn save_sharded(&self, dir: &Path, shards: usize) -> Result<()> {
        let flat = self.flat_params();
        let per = flat.len().div_ceil(shards.max(1)).max(1);
        std::fs::create_dir_all(dir)?;
        for (i, (s, e)) in shard_ranges(flat.len(), per).into_iter().enumerate() {
            let mut ckpt = Checkpoint::default();
            ckpt.insert(&format!("flat/{s}"), &[e - s], flat[s..e].to_vec());
            ckpt.save(&dir.join(format!("dp-shard-{i:03}.ckpt")))?;
        }
        Ok(())
    }

    /// Load parameters from a sharded checkpoint directory, validating
    /// that the shards tile the model's flat layout exactly (no gap,
    /// overlap, or size mismatch passes silently).
    pub fn load_sharded(&mut self, dir: &Path) -> Result<()> {
        let total = self.replicas[0].model.param_count();
        let mut files: Vec<PathBuf> = std::fs::read_dir(dir)
            .map_err(|e| anyhow!("reading checkpoint dir {}: {e}", dir.display()))?
            .filter_map(|r| r.ok().map(|d| d.path()))
            .filter(|p| {
                p.file_name()
                    .and_then(|n| n.to_str())
                    .is_some_and(|n| n.starts_with("dp-shard-") && n.ends_with(".ckpt"))
            })
            .collect();
        files.sort();
        if files.is_empty() {
            bail!("no dp-shard-*.ckpt files in {}", dir.display());
        }
        let mut segments: Vec<(usize, Vec<f32>)> = Vec::new();
        for path in &files {
            let ckpt = Checkpoint::load(path)?;
            for (name, (_, data)) in &ckpt.tensors {
                let off: usize = name
                    .strip_prefix("flat/")
                    .and_then(|s| s.parse().ok())
                    .ok_or_else(|| {
                        anyhow!("{}: unexpected tensor {name:?} in sharded checkpoint",
                                path.display())
                    })?;
                segments.push((off, data.clone()));
            }
        }
        segments.sort_by_key(|(off, _)| *off);
        let mut flat = Vec::with_capacity(total);
        for (off, data) in segments {
            if off != flat.len() {
                bail!(
                    "sharded checkpoint has a gap or overlap at element {off} \
                     (assembled {} elements so far)",
                    flat.len()
                );
            }
            flat.extend_from_slice(&data);
        }
        if flat.len() != total {
            bail!("sharded checkpoint holds {} params, model needs {total}", flat.len());
        }
        self.load_flat(&flat);
        Ok(())
    }
}

fn panic_msg(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::for_all;
    use crate::util::rng::Pcg32;

    #[test]
    fn shard_partition_assigns_every_sample_exactly_once() {
        // property: for random (n, shard), the ranges tile [0, n) in
        // order, every leaf is non-empty and at most `shard` long, and
        // only the last leaf may be ragged
        for_all(
            "shard-partition-tiles",
            31,
            300,
            |r| (1 + r.below(200) as usize, 1 + r.below(40) as usize),
            |&(n, shard)| {
                let ranges = shard_ranges(n, shard);
                let mut expect = 0usize;
                for (i, &(s, e)) in ranges.iter().enumerate() {
                    if s != expect {
                        return Err(format!("leaf {i} starts at {s}, expected {expect}"));
                    }
                    if e <= s || e - s > shard {
                        return Err(format!("leaf {i} = [{s},{e}) is empty or oversized"));
                    }
                    if e - s < shard && i != ranges.len() - 1 {
                        return Err(format!("ragged leaf {i} is not last"));
                    }
                    expect = e;
                }
                if expect != n {
                    return Err(format!("ranges cover {expect} of {n} samples"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn worker_shares_cover_all_leaves_for_any_worker_count() {
        for_all(
            "worker-shares-tile",
            32,
            300,
            |r| (r.below(50) as usize, 1 + r.below(12) as usize),
            |&(tasks, workers)| {
                let shares = worker_shares(tasks, workers);
                if tasks == 0 {
                    return if shares.is_empty() { Ok(()) } else { Err("shares for 0".into()) };
                }
                if shares.len() > workers.min(tasks) {
                    return Err(format!("{} shares for {workers} workers", shares.len()));
                }
                let mut expect = 0usize;
                for &(s, e) in &shares {
                    if s != expect || e <= s {
                        return Err(format!("share [{s},{e}) after {expect}"));
                    }
                    expect = e;
                }
                if expect != tasks {
                    return Err(format!("shares cover {expect} of {tasks} leaves"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn tree_sum_is_bit_identical_across_worker_counts() {
        // satellite: worker counts 1..9 including degenerate N=1 and
        // N > leaf-count must produce identical bits — the tree shape is
        // a function of the leaf index only
        let mut rng = Pcg32::seeded(77);
        for leaf_count in [1usize, 2, 3, 5, 8, 17] {
            let elems = 101;
            let leaves: Vec<Vec<f32>> = (0..leaf_count)
                .map(|_| (0..elems).map(|_| rng.range(-3.0, 3.0)).collect())
                .collect();
            let reference = tree_reduce(leaves.clone(), 1);
            for workers in 2..=9 {
                let got = tree_reduce(leaves.clone(), workers);
                for k in 0..elems {
                    assert_eq!(
                        reference[k].to_bits(),
                        got[k].to_bits(),
                        "leaves={leaf_count} workers={workers} elem={k}"
                    );
                }
            }
            // the scalar twin folds the same tree: reducing each leaf's
            // element k as a scalar list matches the vector reduction
            for k in [0usize, 50, 100] {
                let col: Vec<f32> = leaves.iter().map(|l| l[k]).collect();
                assert_eq!(
                    tree_reduce_scalar(&col).to_bits(),
                    reference[k].to_bits(),
                    "scalar twin diverged at leaves={leaf_count} elem={k}"
                );
            }
        }
    }

    #[test]
    fn tree_reduce_differs_from_sequential_sum_shape() {
        // sanity that the tree is actually a tree: with 4 leaves the fold
        // is (a+b)+(c+d), not ((a+b)+c)+d. Values chosen to expose the
        // association difference in FP32.
        let a = 1.0e8f32;
        let b = 1.0f32;
        let c = -1.0e8f32;
        let d = 1.0f32;
        // tree: (1e8 + 1) absorbs the 1 (ulp at 1e8 is 8), so the fold
        // gives 0; the sequential left fold gives 1
        let tree = tree_reduce_scalar(&[a, b, c, d]);
        assert_eq!(tree.to_bits(), ((a + b) + (c + d)).to_bits());
        assert_ne!(tree.to_bits(), (((a + b) + c) + d).to_bits());
    }

    #[test]
    fn sharded_checkpoint_roundtrip_and_validation() {
        let cfg = DpConfig { workers: 2, shard: 4, lr: 0.05 };
        let base = TrainReplica::for_model("lenet300", MulSpec::Native, 21).unwrap();
        let mut tr = DpTrainer::from_replicas(base.replicas(2), cfg).unwrap();
        let flat = tr.flat_params();
        let dir = std::env::temp_dir().join("approxtrain_dp_ckpt_unit");
        let _ = std::fs::remove_dir_all(&dir);
        for shards in [1usize, 3, 5] {
            let _ = std::fs::remove_dir_all(&dir);
            tr.save_sharded(&dir, shards).unwrap();
            let mut other =
                DpTrainer::new("lenet300", MulSpec::Native, cfg, 909).unwrap();
            other.load_sharded(&dir).unwrap();
            let got = other.flat_params();
            assert_eq!(got.len(), flat.len());
            for i in 0..flat.len() {
                assert_eq!(flat[i].to_bits(), got[i].to_bits(), "shards={shards} param {i}");
            }
        }
        // a missing shard is a loud gap error, not silent garbage
        tr.save_sharded(&dir, 5).unwrap();
        std::fs::remove_file(dir.join("dp-shard-002.ckpt")).unwrap();
        let mut other = DpTrainer::new("lenet300", MulSpec::Native, cfg, 909).unwrap();
        let err = other.load_sharded(&dir).unwrap_err().to_string();
        assert!(err.contains("gap") || err.contains("needs"), "{err}");
        // and a wrong-model load is a size error
        let _ = std::fs::remove_dir_all(&dir);
        tr.save_sharded(&dir, 2).unwrap();
        let mut small = DpTrainer::new("lenet5", MulSpec::Native, cfg, 1).unwrap();
        assert!(small.load_sharded(&dir).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn mask_is_validated_and_enforced_after_each_step() {
        let cfg = DpConfig { workers: 1, shard: 4, lr: 0.05 };
        let base = TrainReplica::for_model("lenet300", MulSpec::Native, 3).unwrap();
        let mut tr = DpTrainer::from_replicas(base.replicas(1), cfg).unwrap();
        let n = tr.flat_params().len();
        // wrong-length masks are rejected before they can corrupt a run
        assert!(tr.set_mask(Some(Mask { keep: vec![true; n + 1] })).is_err());
        assert!(tr.mask().is_none());
        let mut keep = vec![true; n];
        for k in keep.iter_mut().step_by(3) {
            *k = false;
        }
        tr.set_mask(Some(Mask { keep: keep.clone() })).unwrap();
        let dims: usize = tr.replicas[0].model.input_dims().iter().product();
        let mut rng = Pcg32::seeded(11);
        let images: Vec<f32> = (0..8 * dims).map(|_| rng.range(-1.0, 1.0)).collect();
        let labels: Vec<u32> = (0..8).map(|i| i % 10).collect();
        tr.step(&images, &labels).unwrap();
        let flat = tr.flat_params();
        for (i, (&v, &k)) in flat.iter().zip(&keep).enumerate() {
            if !k {
                assert_eq!(v.to_bits(), 0.0f32.to_bits(), "pruned param {i} revived");
            }
        }
        assert!(flat.iter().any(|&v| v != 0.0), "step zeroed everything");
        // clearing the mask lets weights move freely again
        tr.set_mask(None).unwrap();
        assert!(tr.mask().is_none());
    }

    #[test]
    fn config_validation_rejects_nonsense() {
        let base = TrainReplica::for_model("lenet300", MulSpec::Native, 1).unwrap();
        assert!(DpTrainer::from_replicas(vec![], DpConfig { workers: 0, shard: 1, lr: 0.1 })
            .is_err());
        assert!(DpTrainer::from_replicas(
            base.replicas(2),
            DpConfig { workers: 3, shard: 1, lr: 0.1 }
        )
        .is_err());
        assert!(DpTrainer::from_replicas(
            base.replicas(1),
            DpConfig { workers: 1, shard: 0, lr: 0.1 }
        )
        .is_err());
        assert!(DpTrainer::from_replicas(
            base.replicas(1),
            DpConfig { workers: 1, shard: 4, lr: f32::NAN }
        )
        .is_err());
        let mut ok = DpTrainer::from_replicas(
            base.replicas(1),
            DpConfig { workers: 1, shard: 4, lr: 0.1 },
        )
        .unwrap();
        // shape mismatches are typed errors, not panics
        assert!(ok.step(&[0.0; 10], &[1, 2]).is_err());
        assert!(ok.step_accum(&[]).is_err());
        assert!(ok.step_accum(&[(&[][..], &[][..])]).is_err());
    }
}
