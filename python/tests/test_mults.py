"""Multiplier functional models: plausibility + bit-exactness of the
jnp (bitmath) implementations against the numpy mirrors."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import mults
from compile.fp_bits import quantize_mantissa, to_bits
from compile.kernels import bitmath


@pytest.mark.parametrize("name", mults.NAMES)
def test_models_are_plausible_multipliers(name):
    m = mults.by_name(name)
    rng = np.random.default_rng(99)
    a = quantize_mantissa(rng.uniform(-100, 100, 4000).astype(np.float32), m.m)
    b = quantize_mantissa(rng.uniform(-100, 100, 4000).astype(np.float32), m.m)
    c = m.mul(a, b)
    exact = a * b
    nz = exact != 0
    re = np.abs((c[nz] - exact[nz]) / exact[nz])
    assert np.all(re < 0.125), f"{name}: max rel err {re.max()}"
    assert np.all(c[~nz] == 0.0)


def test_fp32_is_exact():
    m = mults.by_name("fp32")
    rng = np.random.default_rng(5)
    a = rng.uniform(-1e10, 1e10, 5000).astype(np.float32)
    b = rng.uniform(-1e3, 1e3, 5000).astype(np.float32)
    assert np.array_equal(to_bits(m.mul(a, b)), to_bits(a * b))


def test_bfloat16_matches_quantized_product():
    m = mults.by_name("bfloat16")
    rng = np.random.default_rng(6)
    a = quantize_mantissa(rng.uniform(-100, 100, 5000).astype(np.float32), 7)
    b = quantize_mantissa(rng.uniform(-100, 100, 5000).astype(np.float32), 7)
    got = m.mul(a, b)
    want = quantize_mantissa(a * b, 7)
    assert np.array_equal(to_bits(got), to_bits(want))


def test_error_profile_ordering():
    rng = np.random.default_rng(7)
    a = quantize_mantissa(rng.uniform(1, 2, 20000).astype(np.float32), 7)
    b = quantize_mantissa(rng.uniform(1, 2, 20000).astype(np.float32), 7)
    exact = a.astype(np.float64) * b.astype(np.float64)

    def profile(name):
        c = mults.by_name(name).mul(a, b).astype(np.float64)
        re = (c - exact) / exact
        return np.abs(re).mean(), re.mean()

    mred_mit, bias_mit = profile("mit16")
    mred_afm, bias_afm = profile("afm16")
    mred_realm, _ = profile("realm16")
    assert mred_afm < mred_mit
    assert mred_realm < mred_mit
    assert abs(bias_afm) < 0.01
    assert bias_mit < -0.02  # Mitchell under-estimates


DIRECT_JNP = ["afm32", "afm16", "mit16", "realm16", "bfloat16", "fp16"]


@pytest.mark.parametrize("name", DIRECT_JNP)
def test_jnp_direct_matches_numpy_mirror(name):
    """The in-graph (Pallas-able) bit math must be bit-exact with the numpy
    functional model — this is what ties L1 to the Rust oracle."""
    m = mults.by_name(name)
    rng = np.random.default_rng(11)
    a = quantize_mantissa((rng.uniform(-50, 50, 8000)).astype(np.float32), m.m)
    b = quantize_mantissa((rng.uniform(-50, 50, 8000)).astype(np.float32), m.m)
    got = np.asarray(bitmath.direct_mul(jnp.asarray(a), jnp.asarray(b), name))
    want = m.mul(a, b)
    # jnp path returns unsigned zero where numpy mirror keeps the sign
    eq = (to_bits(got) == to_bits(want)) | ((got == 0) & (want == 0))
    bad = np.flatnonzero(~eq)
    assert bad.size == 0, f"{name}: first mismatch {a[bad[0]]} * {b[bad[0]]}: " \
                          f"{got[bad[0]]} vs {want[bad[0]]}"


@settings(max_examples=200, deadline=None)
@given(st.floats(width=32, allow_nan=False, allow_infinity=False,
                 allow_subnormal=False, min_value=-2.0**96, max_value=2.0**96),
       st.floats(width=32, allow_nan=False, allow_infinity=False,
                 allow_subnormal=False, min_value=-2.0**96, max_value=2.0**96))
def test_afm16_hypothesis_scalar(x, y):
    a = quantize_mantissa(np.float32(x), 7)
    b = quantize_mantissa(np.float32(y), 7)
    m = mults.by_name("afm16")
    got = np.asarray(bitmath.direct_mul(jnp.asarray(a), jnp.asarray(b), "afm16"))
    want = m.mul(a, b)
    assert to_bits(got) == to_bits(want) or (got == 0 and want == 0)


def test_unknown_multiplier_raises():
    with pytest.raises(KeyError):
        mults.by_name("nope")
