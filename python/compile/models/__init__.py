"""Layer-2 model definitions (functional JAX, params as flat name->array
dicts so the artifact manifest can enumerate them deterministically)."""

from . import lenet, resnet  # noqa: F401

MODELS = {
    "lenet300": lenet.lenet300,
    "lenet5": lenet.lenet5,
    "resnet18": resnet.resnet18,
    "resnet34": resnet.resnet34,
    "resnet50": resnet.resnet50,
}


def by_name(name: str):
    if name not in MODELS:
        raise KeyError(f"unknown model {name!r}")
    return MODELS[name]
