//! Report emitters: markdown tables + CSV files under `results/`.

use std::fmt::Write as _;
use std::path::Path;

use anyhow::Result;

/// A simple markdown table builder.
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "table row arity");
        self.rows.push(cells);
    }

    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        writeln!(out, "### {}\n", self.title).unwrap();
        writeln!(out, "| {} |", self.header.join(" | ")).unwrap();
        writeln!(out, "|{}|", vec!["---"; self.header.len()].join("|")).unwrap();
        for r in &self.rows {
            writeln!(out, "| {} |", r.join(" | ")).unwrap();
        }
        out.push('\n');
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = self.header.join(",");
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.join(","));
            out.push('\n');
        }
        out
    }
}

/// Write a string to `results/<name>` (creating the directory).
pub fn write_result(results_dir: &Path, name: &str, content: &str) -> Result<()> {
    std::fs::create_dir_all(results_dir)?;
    std::fs::write(results_dir.join(name), content)?;
    Ok(())
}

/// Write a committed perf record (`BENCH_*.json`) at the repo root — the
/// policy shared by `bench-gemm` and `bench-conv`: only explicit
/// full-budget runs call this; quick/smoke runs stay in `results/`.
/// `CARGO_MANIFEST_DIR` is exactly the repo root for the documented
/// `cargo run`/`cargo bench` flows regardless of invocation cwd; an
/// installed binary on a machine without the source tree falls back to
/// the cwd.
pub fn write_root_record(name: &str, payload: &str) -> Result<()> {
    let manifest_dir = Path::new(env!("CARGO_MANIFEST_DIR"));
    let root_record = if manifest_dir.is_dir() {
        manifest_dir.join(name)
    } else {
        Path::new(name).to_path_buf()
    };
    std::fs::write(&root_record, payload)
        .map_err(|e| anyhow::anyhow!("writing {}: {e}", root_record.display()))
}

/// Format a seconds value the way the paper's tables do.
pub fn fmt_time(s: f64) -> String {
    crate::util::fmt_duration(s)
}

/// Format a ratio like "4.2x".
pub fn fmt_ratio(r: f64) -> String {
    format!("{r:.1}x")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders() {
        let mut t = Table::new("Demo", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### Demo"));
        assert!(md.contains("| 1 | 2 |"));
        assert_eq!(t.to_csv(), "a,b\n1,2\n");
    }

    #[test]
    #[should_panic(expected = "table row arity")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }
}
