//! Micro-kernel remainder-edge acceptance suite.
//!
//! The register-blocked `MR x NR` tile drain ([`MulBackend::mul_microtile`]
//! via `gemm_tiled_*`) must be bit-identical to the per-element scalar
//! oracle `gemm_scalar_reference` at **every** `(m mod MR, n mod NR)`
//! residue — the edges where the drain falls back to narrower micro-tiles
//! (down to `1 x 1`) — for all three simulation strategies and under the
//! pool scheduler. A steady-state check also pins that a second
//! micro-kernel GEMM at the same geometry performs no recycled-buffer
//! growth (the micro-tile accumulator block lives on the stack, and the
//! `NR`-strip `B` packing reuses the same `KC x NC` buffer footprint).

use approxtrain::amsim::AmSim;
use approxtrain::kernels::gemm::{gemm_scalar_reference, gemm_tiled_with, TileConfig};
use approxtrain::kernels::{buffer_growth_events, MulKernel};
use approxtrain::lut::MantissaLut;
use approxtrain::mult::registry;
use approxtrain::util::rng::Pcg32;

fn rand_vec(rng: &mut Pcg32, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.range(-2.0, 2.0)).collect()
}

fn for_each_strategy(f: impl Fn(&MulKernel, &str)) {
    let model = registry::by_name("afm16").unwrap();
    let lut = MantissaLut::generate(model.as_ref());
    f(&MulKernel::Native, "native");
    f(&MulKernel::Direct(model.as_ref()), "direct");
    f(&MulKernel::Lut(AmSim::new(&lut)), "lut");
}

fn assert_bits(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for i in 0..got.len() {
        assert_eq!(
            got[i].to_bits(),
            want[i].to_bits(),
            "{what} idx {i}: {} vs {}",
            got[i],
            want[i]
        );
    }
}

/// Every `(m mod MR, n mod NR)` residue of the default 4x8 micro-tile, at
/// a tile geometry small enough that the shapes also straddle tile edges
/// and the contraction splits across `KC` blocks with a remainder — for
/// native / direct / LUT, single-lane and pool-threaded.
#[test]
fn every_residue_matches_scalar_oracle_at_default_micro_tile() {
    let cfg = TileConfig { mc: 8, kc: 16, nc: 16, mr: 4, nr: 8 };
    let k = 37; // two full KC blocks + a 5-step remainder
    for_each_strategy(|mul, name| {
        for m in 12..16 {
            // m % 4 covers 0..=3
            for n in 16..24 {
                // n % 8 covers 0..=7
                let mut rng = Pcg32::seeded(8800 + (m * 100 + n) as u64);
                let a = rand_vec(&mut rng, m * k);
                let b = rand_vec(&mut rng, k * n);
                let mut want = vec![0.0f32; m * n];
                gemm_scalar_reference(mul, &a, &b, &mut want, m, k, n);
                for threads in [1usize, 8] {
                    let mut got = vec![0.0f32; m * n];
                    gemm_tiled_with(mul, cfg, &a, &b, &mut got, m, k, n, threads);
                    assert_bits(
                        &got,
                        &want,
                        &format!("[{name}] ({m},{k},{n}) residue ({},{}) t={threads}", m % 4, n % 8),
                    );
                }
            }
        }
    });
}

/// The same residue sweep at a non-default, odd micro-tile shape (3x5),
/// so remainder handling is not accidentally specialized to the default
/// powers of two.
#[test]
fn every_residue_matches_scalar_oracle_at_odd_micro_tile() {
    let cfg = TileConfig { mc: 6, kc: 11, nc: 10, mr: 3, nr: 5 };
    let k = 23;
    for_each_strategy(|mul, name| {
        for m in 9..12 {
            // m % 3 covers 0..=2
            for n in 10..15 {
                // n % 5 covers 0..=4
                let mut rng = Pcg32::seeded(8900 + (m * 100 + n) as u64);
                let a = rand_vec(&mut rng, m * k);
                let b = rand_vec(&mut rng, k * n);
                let mut want = vec![0.0f32; m * n];
                gemm_scalar_reference(mul, &a, &b, &mut want, m, k, n);
                let mut got = vec![0.0f32; m * n];
                gemm_tiled_with(mul, cfg, &a, &b, &mut got, m, k, n, 1);
                assert_bits(
                    &got,
                    &want,
                    &format!("[{name}] ({m},{k},{n}) residue ({},{})", m % 3, n % 5),
                );
            }
        }
    });
}

/// Problems smaller than one micro-tile in either dimension (m < MR,
/// n < NR) run entirely on remainder paths.
#[test]
fn degenerate_shapes_smaller_than_the_micro_tile() {
    for_each_strategy(|mul, name| {
        for (m, k, n) in [(1usize, 1usize, 1usize), (2, 9, 3), (1, 40, 7), (3, 17, 1)] {
            let mut rng = Pcg32::seeded(9000 + (m * k * n) as u64);
            let a = rand_vec(&mut rng, m * k);
            let b = rand_vec(&mut rng, k * n);
            let mut want = vec![0.0f32; m * n];
            gemm_scalar_reference(mul, &a, &b, &mut want, m, k, n);
            let mut got = vec![0.0f32; m * n];
            gemm_tiled_with(mul, TileConfig::DEFAULT, &a, &b, &mut got, m, k, n, 1);
            assert_bits(&got, &want, &format!("[{name}] tiny ({m},{k},{n})"));
        }
    });
}

/// Steady-state no-alloc check: after a warm first micro-kernel GEMM, a
/// second run at the same geometry must not grow the recycled
/// thread-local pack buffers (single lane, so this thread's growth
/// counter observes every packing).
#[test]
fn second_micro_kernel_gemm_reuses_recycled_buffers() {
    let model = registry::by_name("afm16").unwrap();
    let lut = MantissaLut::generate(model.as_ref());
    let mul = MulKernel::Lut(AmSim::new(&lut));
    let (m, k, n) = (21, 65, 19);
    let mut rng = Pcg32::seeded(9100);
    let a = rand_vec(&mut rng, m * k);
    let b = rand_vec(&mut rng, k * n);
    let mut first = vec![0.0f32; m * n];
    gemm_tiled_with(&mul, TileConfig::DEFAULT, &a, &b, &mut first, m, k, n, 1);
    let before = buffer_growth_events();
    let mut second = vec![0.0f32; m * n];
    gemm_tiled_with(&mul, TileConfig::DEFAULT, &a, &b, &mut second, m, k, n, 1);
    assert_eq!(
        buffer_growth_events(),
        before,
        "steady-state micro-kernel GEMM must not grow the recycled buffers"
    );
    assert_bits(&second, &first, "steady-state determinism");
}
