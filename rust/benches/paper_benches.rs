//! Benchmark harness — regenerates every table and figure of the paper's
//! evaluation (criterion is unavailable offline; this is a custom harness
//! over `approxtrain::util::timer` + the experiment functions).
//!
//! ```sh
//! cargo bench                 # quick settings, all experiments
//! cargo bench -- gemm         # CPU GEMM perf record -> results/BENCH_gemm.json
//! cargo bench -- gemm --full  # ...and refresh the committed root BENCH_gemm.json
//! cargo bench -- gemm --smoke # tiny CI smoke sizes (results/ only)
//! cargo bench -- conv         # implicit vs materialized conv -> results/BENCH_conv.json
//! cargo bench -- serve        # multi-lane serving sweep -> results/BENCH_serve.json
//! cargo bench -- serve --net  # ...plus the networked serving tier sweep
//! cargo bench -- train        # data-parallel training sweep -> results/BENCH_train.json
//! cargo bench -- fig6         # one experiment
//! cargo bench -- all --full   # full (slow) settings
//! ```
//!
//! Results are printed and written under `results/`. The `gemm` experiment
//! needs no artifacts (pure CPU kernels): the native / direct / LUT
//! comparison of paper Fig 6 for both the row-sliced panel kernel and the
//! cache-blocked packed tiled kernel (drained by the register-blocked
//! MRxNR micro-kernel, with a 1x1 per-element-drain ablation row), the
//! batched-panel-vs-per-element-dispatch, tiled-vs-panel and
//! micro-vs-scalar-drain speedups, and an autotune probe sweeping the
//! micro-tile shape alongside the tile shape at the largest size — every
//! timed path bit-exactness-gated against the scalar oracle first. Only
//! an explicit full-budget `gemm` run refreshes the committed repo-root
//! `BENCH_gemm.json` (see docs/BENCHMARKS.md).

use std::path::Path;

use approxtrain::coordinator::experiments as exp;
use approxtrain::runtime::executor::Engine;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).filter(|a| a != "--bench").collect();
    let which = args.iter().find(|a| !a.starts_with("--")).cloned().unwrap_or("all".into());
    let quick = !args.iter().any(|a| a == "--full");
    let smoke = args.iter().any(|a| a == "--smoke");
    let artifacts = Path::new("artifacts");
    let results = Path::new("results");

    let mut out = String::new();
    let wants = |name: &str| which == name || which == "all";

    if wants("fig1") {
        out.push_str(&exp::fig1(results)?);
    }

    if wants("gemm") {
        // The committed root perf record is only refreshed by an explicit,
        // full-budget run (`cargo bench -- gemm --full`); smoke/quick/"all"
        // runs write results/BENCH_gemm.json but keep throwaway low-budget
        // numbers out of the committed record.
        let size = if smoke { 48 } else { 256 };
        let record_root = which == "gemm" && !smoke && !quick;
        out.push_str(&exp::bench_gemm(results, size, quick || smoke, record_root)?);
    }

    if wants("conv") {
        // Implicit-GEMM conv vs the materialized-im2col route (pure CPU,
        // bit-exactness-gated). Same root-record policy as `gemm`.
        let record_root = which == "conv" && !smoke && !quick;
        out.push_str(&exp::bench_conv(results, quick || smoke, record_root)?);
    }

    if wants("serve") {
        // Multi-lane batching server sweep over the pure-Rust executor
        // backend (lanes x offered load x strategy), every accepted reply
        // bit-exactness-gated against a single-lane reference forward.
        // --net adds the loopback TCP sweep through the fault-tolerant
        // serving tier (connections x lanes x priority mix, deadlines on
        // the wire) under the same bit gate. Same root-record policy as
        // `gemm`.
        let net = args.iter().any(|a| a == "--net");
        let record_root = which == "serve" && !smoke && !quick;
        out.push_str(&exp::bench_serve(results, quick || smoke, record_root, net)?);
    }

    if wants("train") {
        // Deterministic data-parallel training sweep (workers x strategy
        // x model) over the pure-Rust executors; every multi-worker run
        // bit-exactness-gated (loss curve + final params) against its
        // 1-worker twin. Same root-record policy as `gemm`.
        let record_root = which == "train" && !smoke && !quick;
        out.push_str(&exp::bench_train(results, quick || smoke, record_root)?);
    }

    if !artifacts.join("manifest.json").exists() {
        println!(
            "artifacts/ not built — only fig1/gemm/conv/serve/train available. Run `make artifacts`."
        );
        print!("{out}");
        approxtrain::coordinator::report::write_result(results, "bench_report.md", &out)?;
        return Ok(());
    }
    let mut engine = Engine::new(artifacts)?;

    if wants("fig6") {
        out.push_str(&exp::fig6(&mut engine, results, if quick { 128 } else { 256 }, quick)?);
    }
    if wants("fig10") || wants("table3") {
        out.push_str(&exp::fig10_table3(&mut engine, artifacts, results, quick)?);
    }
    if wants("table4") {
        out.push_str(&exp::table4(&mut engine, artifacts, results, quick)?);
    }
    if wants("fig11") {
        out.push_str(&exp::fig11(&mut engine, artifacts, results, quick)?);
    }
    if wants("table5") {
        out.push_str(&exp::table5_6(&mut engine, artifacts, results, true, quick)?);
    }
    if wants("table6") {
        out.push_str(&exp::table5_6(&mut engine, artifacts, results, false, quick)?);
    }
    if wants("fig12") {
        out.push_str(&exp::fig12(&mut engine, results, quick)?);
    }
    if wants("ablation") {
        out.push_str(&ablations(&mut engine, quick)?);
    }

    println!("{out}");
    approxtrain::coordinator::report::write_result(results, "bench_report.md", &out)?;
    Ok(())
}

/// Design-choice ablations called out in DESIGN.md.
fn ablations(engine: &mut Engine, quick: bool) -> anyhow::Result<String> {
    use approxtrain::coordinator::report::{fmt_ratio, fmt_time, Table};
    use approxtrain::kernels::im2col::{dilate_explicit, im2col_forward, im2col_weight_grad};
    use approxtrain::kernels::Conv2dGeom;
    use approxtrain::util::rng::Pcg32;
    use approxtrain::util::timer::bench_budget;
    let _ = engine;
    let budget = if quick { 0.3 } else { 2.0 };

    // Ablation 1: fused dilation (paper §VI-B.1) vs explicit dilation
    let g = Conv2dGeom {
        batch: 16,
        in_h: 28,
        in_w: 28,
        in_c: 8,
        k_h: 3,
        k_w: 3,
        out_c: 16,
        stride: 2,
        pad: 1,
    };
    let mut rng = Pcg32::seeded(9);
    let act: Vec<f32> =
        (0..g.batch * g.in_h * g.in_w * g.in_c).map(|_| rng.range(-1.0, 1.0)).collect();
    let q = g.batch * g.out_h() * g.out_w();
    let mut cols = vec![0.0f32; g.col_cols() * q];
    let fused = bench_budget("fused", 1, 3, budget, || {
        im2col_weight_grad(&g, &act, &mut cols);
    });
    // explicit (the naive method the paper §VI-B.1 rejects): materialize
    // the dilated error map, then extract activation patches at *every*
    // stride-1 position — a larger column matrix plus an extra buffer.
    let errors: Vec<f32> = (0..q * g.out_c).map(|_| rng.range(-1.0, 1.0)).collect();
    let g1 = Conv2dGeom { stride: 1, ..g }; // stride-1 (dilated) geometry
    let q1 = g1.batch * g1.out_h() * g1.out_w();
    let mut cols1 = vec![0.0f32; g1.col_cols() * q1];
    let explicit = bench_budget("explicit", 1, 3, budget, || {
        let (_dilated, _dh, _dw) = dilate_explicit(&g, &errors); // extra buffer
        im2col_weight_grad(&g1, &act, &mut cols1); // stride-1 patch pass
    });
    let _ = im2col_forward as fn(&Conv2dGeom, &[f32], &mut [f32]); // (re-exported use)
    let mut t = Table::new(
        "Ablation — fused dilation (weight grad) vs explicit dilated pass",
        &["variant", "time", "ratio"],
    );
    t.row(vec!["fused skip-read im2col (paper)".into(), fmt_time(fused.median_s()),
               fmt_ratio(1.0)]);
    t.row(vec![
        "explicit dilation + stride-1 pass".into(),
        fmt_time(explicit.median_s()),
        fmt_ratio(explicit.median_s() / fused.median_s()),
    ]);

    // Ablation 2: LUT entry width — 4-byte pre-shifted entries (paper
    // footnote 1) vs 2-byte packed entries needing a shift on every fetch
    use approxtrain::lut::MantissaLut;
    use approxtrain::mult::registry;
    let model = registry::by_name("afm16").unwrap();
    let lut = MantissaLut::generate(model.as_ref());
    let packed: Vec<u16> =
        lut.entries.iter().map(|&e| (((e >> 23) << 7) | ((e & 0x7FFFFF) >> 16)) as u16).collect();
    let mut rng = Pcg32::seeded(10);
    let n = 1 << 18;
    let xs: Vec<u32> = (0..n).map(|_| rng.next_u32() & 0x3FFF).collect();
    let mut acc = 0u32;
    let four = bench_budget("4B", 1, 3, budget, || {
        acc = 0;
        for &i in &xs {
            acc = acc.wrapping_add(lut.entries[i as usize]);
        }
    });
    let two = bench_budget("2B", 1, 3, budget, || {
        acc = 0;
        for &i in &xs {
            let e = packed[i as usize] as u32;
            // unpack: shift mantissa back into FP32 position + carry
            acc = acc.wrapping_add(((e >> 7) << 23) | ((e & 0x7F) << 16));
        }
    });
    std::hint::black_box(acc);
    t.row(vec!["4-byte pre-shifted LUT entries (paper)".into(), fmt_time(four.median_s()),
               fmt_ratio(1.0)]);
    t.row(vec![
        "2-byte packed entries (+unpack shifts)".into(),
        fmt_time(two.median_s()),
        fmt_ratio(two.median_s() / four.median_s()),
    ]);
    Ok(t.to_markdown())
}
