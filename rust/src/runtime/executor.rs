//! Execution engine: compiles artifacts on demand, caches executables, and
//! runs them with named buffers. This is the only place where the L3
//! coordinator touches PJRT.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::artifact::{Artifact, Dtype, Manifest};
use super::Runtime;

/// Host-side tensor value matching a [`TensorSpec`].
#[derive(Clone, Debug)]
pub enum Value {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U32(Vec<u32>),
}

impl Value {
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Value::F32(v) => Ok(v),
            _ => bail!("value is not f32"),
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Value::F32(v) => v.len(),
            Value::I32(v) => v.len(),
            Value::U32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn scalar_f32(&self) -> Result<f32> {
        let v = self.as_f32()?;
        if v.len() != 1 {
            bail!("expected scalar, got {} elements", v.len());
        }
        Ok(v[0])
    }
}

/// Compiles and caches executables; executes with host values.
pub struct Engine {
    runtime: Runtime,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Engine {
    pub fn new(artifacts_dir: &Path) -> Result<Engine> {
        let runtime = Runtime::cpu()?;
        let manifest = Manifest::load(artifacts_dir)?;
        Ok(Engine { runtime, manifest, cache: HashMap::new() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch from cache) an artifact's executable.
    pub fn prepare(&mut self, name: &str) -> Result<()> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let art = self.manifest.get(name)?.clone();
        let exe = self
            .runtime
            .compile_file(&self.manifest.hlo_path(&art))
            .with_context(|| format!("compiling artifact {name}"))?;
        self.cache.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute `name` with positional values; validates count, length and
    /// dtype against the manifest, returns outputs in manifest order.
    pub fn run(&mut self, name: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        self.prepare(name)?;
        let art = self.manifest.get(name)?.clone();
        validate_inputs(&art, inputs)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .zip(&art.inputs)
            .map(|(v, spec)| {
                let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
                Ok(match v {
                    Value::F32(data) => xla::Literal::vec1(data).reshape(&dims)?,
                    Value::I32(data) => xla::Literal::vec1(data).reshape(&dims)?,
                    Value::U32(data) => xla::Literal::vec1(data).reshape(&dims)?,
                })
            })
            .collect::<Result<_>>()?;
        let exe = self.cache.get(name).expect("prepared above");
        let result = exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {name}"))?;
        // aot.py lowers with return_tuple=True
        let parts = result.to_tuple().with_context(|| format!("untupling result of {name}"))?;
        if parts.len() != art.outputs.len() {
            bail!("{name}: {} outputs, manifest says {}", parts.len(), art.outputs.len());
        }
        parts
            .into_iter()
            .zip(&art.outputs)
            .map(|(lit, spec)| {
                Ok(match spec.dtype {
                    Dtype::F32 => Value::F32(lit.to_vec::<f32>()?),
                    Dtype::I32 => Value::I32(lit.to_vec::<i32>()?),
                    Dtype::U32 => Value::U32(lit.to_vec::<u32>()?),
                })
            })
            .collect()
    }
}

fn validate_inputs(art: &Artifact, inputs: &[Value]) -> Result<()> {
    if inputs.len() != art.inputs.len() {
        bail!("{}: got {} inputs, manifest says {}", art.name, inputs.len(), art.inputs.len());
    }
    for (v, spec) in inputs.iter().zip(&art.inputs) {
        if v.len() != spec.elements() {
            bail!(
                "{}: input {} has {} elements, expected {} {:?}",
                art.name,
                spec.name,
                v.len(),
                spec.elements(),
                spec.shape
            );
        }
        let ok = matches!(
            (v, spec.dtype),
            (Value::F32(_), Dtype::F32) | (Value::I32(_), Dtype::I32) | (Value::U32(_), Dtype::U32)
        );
        if !ok {
            bail!("{}: input {} dtype mismatch ({:?})", art.name, spec.name, spec.dtype);
        }
    }
    Ok(())
}
