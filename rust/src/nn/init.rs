//! Parameter initialization driven by the artifact manifest.
//!
//! `aot.py` exports each parameter's init kind (`he_normal`/`zeros`/`ones`)
//! and fan-in; the coordinator initializes deterministically from a seed so
//! every multiplier configuration trains from bit-identical weights (the
//! paper's same-random-seed methodology, §VIII-A).

use anyhow::{bail, Result};

use crate::runtime::artifact::{Artifact, Role};
use crate::runtime::executor::Value;
use crate::util::json::Json;
use crate::util::rng::Pcg32;

/// Initialize all `param` inputs of an artifact. Returns values in the
/// artifact's positional param order.
pub fn init_params(art: &Artifact, seed: u64, raw_manifest: &Json) -> Result<Vec<Value>> {
    // init metadata lives in the manifest json (role specs don't carry it),
    // so re-read the artifact's input entries
    let arts = raw_manifest
        .get("artifacts")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("bad manifest"))?;
    let entry = arts
        .iter()
        .find(|a| a.get("name").and_then(Json::as_str) == Some(&art.name))
        .ok_or_else(|| anyhow::anyhow!("artifact {} missing from raw manifest", art.name))?;
    let inputs = entry.get("inputs").and_then(Json::as_arr).unwrap();

    let mut out = Vec::new();
    // one independent stream per parameter so ordering changes don't shift
    // other parameters' values
    for (pi, idx) in art.input_indices(Role::Param).into_iter().enumerate() {
        let spec = &art.inputs[idx];
        let meta = &inputs[idx];
        let init = meta.get("init").and_then(Json::as_str).unwrap_or("zeros");
        let fan_in = meta.get("fan_in").and_then(Json::as_usize).unwrap_or(0);
        let n = spec.elements();
        let mut rng = Pcg32::new(seed, 0x1111 + pi as u64);
        let data = match init {
            "he_normal" => {
                if fan_in == 0 {
                    bail!("{}: he_normal without fan_in", spec.name);
                }
                let std = (2.0 / fan_in as f32).sqrt();
                (0..n).map(|_| std * rng.normal()).collect()
            }
            "zeros" => vec![0.0; n],
            "ones" => vec![1.0; n],
            other => bail!("{}: unknown init {other:?}", spec.name),
        };
        out.push(Value::F32(data));
    }
    Ok(out)
}

/// Zero velocity buffers matching an artifact's `velocity` inputs.
pub fn init_velocities(art: &Artifact) -> Vec<Value> {
    art.input_indices(Role::Velocity)
        .into_iter()
        .map(|idx| Value::F32(vec![0.0; art.inputs[idx].elements()]))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::Manifest;
    use std::path::Path;

    fn manifest_json() -> &'static str {
        r#"{"artifacts": [{"name": "m_train_lut", "file": "f", "model": "m",
            "phase": "train", "mode": "lut",
            "inputs": [
              {"name": "w", "role": "param", "shape": [4, 3], "dtype": "f32",
               "init": "he_normal", "fan_in": 4},
              {"name": "b", "role": "param", "shape": [3], "dtype": "f32",
               "init": "zeros"},
              {"name": "g", "role": "param", "shape": [3], "dtype": "f32",
               "init": "ones"},
              {"name": "vel:w", "role": "velocity", "shape": [4, 3], "dtype": "f32"},
              {"name": "x", "role": "input", "shape": [2, 4], "dtype": "f32"}
            ],
            "outputs": []}]}"#
    }

    #[test]
    fn init_kinds_and_determinism() {
        let m = Manifest::parse(Path::new("/tmp"), manifest_json()).unwrap();
        let art = m.get("m_train_lut").unwrap();
        let raw = Json::parse(manifest_json()).unwrap();
        let p1 = init_params(art, 42, &raw).unwrap();
        let p2 = init_params(art, 42, &raw).unwrap();
        let p3 = init_params(art, 43, &raw).unwrap();
        assert_eq!(p1.len(), 3);
        assert_eq!(p1[0].as_f32().unwrap(), p2[0].as_f32().unwrap());
        assert_ne!(p1[0].as_f32().unwrap(), p3[0].as_f32().unwrap());
        assert!(p1[1].as_f32().unwrap().iter().all(|&v| v == 0.0));
        assert!(p1[2].as_f32().unwrap().iter().all(|&v| v == 1.0));
        // he scale: std ~ sqrt(2/4)
        let w = p1[0].as_f32().unwrap();
        let std = (w.iter().map(|v| v * v).sum::<f32>() / w.len() as f32).sqrt();
        assert!(std > 0.2 && std < 1.5, "std {std}");
        let vels = init_velocities(art);
        assert_eq!(vels.len(), 1);
        assert_eq!(vels[0].len(), 12);
    }
}
