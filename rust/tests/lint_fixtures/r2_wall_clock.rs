//! Planted R2 violations: clock reads and hash-ordered collections.
//! The lint test assigns this file a deterministic-module virtual path;
//! the `#[cfg(test)]` module at the bottom must stay exempt.

use std::collections::HashMap;
use std::time::Instant;

pub fn stamp() -> u128 {
    let t0 = Instant::now();
    let mut m: HashMap<u32, u32> = HashMap::new();
    m.insert(1, 2);
    t0.elapsed().as_nanos() + m.len() as u128
}

#[cfg(test)]
mod tests {
    use std::time::Instant;

    #[test]
    fn clocks_are_fine_in_tests() {
        let _ = Instant::now();
    }
}
