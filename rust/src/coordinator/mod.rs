//! Layer-3 coordinator: the pieces that turn compiled artifacts + LUTs +
//! datasets into the paper's experiments.
//!
//! * [`trainer`] — the training/evaluation driver over the PJRT engine
//!   (one fused train-step call per batch; Python never runs here).
//! * [`pruning`] — magnitude pruning with a polynomial-decay schedule
//!   (Fig 11).
//! * [`backend`] — the pluggable [`backend::InferBackend`] executors the
//!   server lanes drive: the PJRT artifact path and the pure-Rust
//!   (ATxC) executor path.
//! * [`server`] — the multi-lane batching inference server: a bounded
//!   admission queue feeding N worker lanes, each dynamically batching
//!   onto its own backend replica.
//! * [`wire`] — the length-prefixed, CRC-framed binary protocol the
//!   networked tier speaks (pure codec, no sockets).
//! * [`net`] — the fault-tolerant TCP serving tier over the lane server:
//!   deadlines, priority load shedding, multi-tenant registry with
//!   epoch-guarded LUT hot-swap, graceful drain, and a retrying client.
//! * [`faults`] — the deterministic fault-injection registry the
//!   `serve_net` suite scripts (lane kills/delays, admission delays,
//!   raw-socket peer-misbehavior helpers).
//! * [`data_parallel`] — deterministic data-parallel training over the
//!   pure-Rust executors: fixed-shard minibatch decomposition + a
//!   fixed-order binary gradient reduction tree, so the loss curve is
//!   bit-identical for any worker count.
//! * [`experiments`] — the harness that regenerates every paper
//!   table/figure (also callable from `cargo bench`).
//! * [`report`] — markdown/CSV emitters for EXPERIMENTS.md.
pub mod backend;
pub mod data_parallel;
pub mod experiments;
pub mod faults;
pub mod net;
pub mod pruning;
pub mod report;
pub mod server;
pub mod trainer;
pub mod wire;
