//! Conv-layer gradient correctness and tiled-GEMM bit-identity.
//!
//! Two nets:
//! 1. finite-difference checks of `amconv2d::weight_grad` and
//!    `amconv2d::input_grad` under the *fp32 multiplier* (the exact
//!    `MulKernel::Direct(fp32)` functional model), tolerance-based;
//! 2. bit-identity of all three conv GEMMs (forward, weight-grad,
//!    preceding-layer-grad) against `gemm_scalar_reference` run over the
//!    same im2col matrices, at odd geometries (stride 2, pad 1,
//!    non-square input) — for every simulation strategy, on the tiled
//!    packed GEMM path the layers actually use (`gemm_auto`).

use approxtrain::amsim::AmSim;
use approxtrain::kernels::gemm::gemm_scalar_reference;
use approxtrain::kernels::im2col::{im2col_forward, im2col_plg, im2col_weight_grad};
use approxtrain::kernels::transpose_reverse::transpose_reverse;
use approxtrain::kernels::{Conv2dGeom, MulKernel};
use approxtrain::layers::amconv2d;
use approxtrain::lut::MantissaLut;
use approxtrain::mult::registry;
use approxtrain::tensor::Tensor;
use approxtrain::util::rng::Pcg32;

fn rand_tensor(shape: &[usize], rng: &mut Pcg32) -> Tensor {
    let n = shape.iter().product();
    Tensor::from_vec(shape, (0..n).map(|_| rng.range(-1.0, 1.0)).collect())
}

/// Finite-difference check of both backward kernels under the fp32
/// multiplier functional model (exact, but exercised through the Direct
/// dispatch path the approximate designs use).
#[test]
fn gradients_match_finite_differences_under_fp32_direct() {
    let fp32 = registry::by_name("fp32").unwrap();
    let mul = MulKernel::Direct(fp32.as_ref());
    let mut rng = Pcg32::seeded(71);
    for (stride, pad) in [(1usize, 1usize), (2, 1)] {
        let x = rand_tensor(&[1, 6, 6, 2], &mut rng);
        let w = rand_tensor(&[3, 3, 2, 3], &mut rng);
        let y = amconv2d::forward(&mul, &x, &w, stride, pad);
        let dy = rand_tensor(&y.shape, &mut rng);
        let dw = amconv2d::weight_grad(&mul, &x, &dy, &w.shape, stride, pad);
        let dx = amconv2d::input_grad(&mul, &dy, &w, &x.shape, stride, pad);

        let loss = |x: &Tensor, w: &Tensor| -> f32 {
            let y = amconv2d::forward(&mul, x, w, stride, pad);
            y.data.iter().zip(&dy.data).map(|(a, b)| a * b).sum()
        };
        let eps = 1e-2;
        for i in (0..w.len()).step_by(5) {
            let mut wp = w.clone();
            wp.data[i] += eps;
            let mut wm = w.clone();
            wm.data[i] -= eps;
            let num = (loss(&x, &wp) - loss(&x, &wm)) / (2.0 * eps);
            assert!(
                (num - dw.data[i]).abs() < 2e-2,
                "stride {stride} pad {pad}: dw[{i}] {num} vs {}",
                dw.data[i]
            );
        }
        for i in (0..x.len()).step_by(7) {
            let mut xp = x.clone();
            xp.data[i] += eps;
            let mut xm = x.clone();
            xm.data[i] -= eps;
            let num = (loss(&xp, &w) - loss(&xm, &w)) / (2.0 * eps);
            assert!(
                (num - dx.data[i]).abs() < 2e-2,
                "stride {stride} pad {pad}: dx[{i}] {num} vs {}",
                dx.data[i]
            );
        }
    }
}

/// The three conv GEMMs, replayed through the per-element scalar oracle
/// over the layer's own im2col matrices, must match the layer outputs
/// bit for bit — at stride 2, pad 1, on a non-square input, for every
/// strategy (the acceptance contract of the tiled kernel as seen from
/// the conv layer).
#[test]
fn conv_gemms_bitwise_match_scalar_reference_at_odd_shapes() {
    let model = registry::by_name("afm16").unwrap();
    let lut = MantissaLut::generate(model.as_ref());
    let strategies = [
        MulKernel::Native,
        MulKernel::Direct(model.as_ref()),
        MulKernel::Lut(AmSim::new(&lut)),
    ];
    let (stride, pad) = (2usize, 1usize);
    let g = Conv2dGeom {
        batch: 2,
        in_h: 7,
        in_w: 9,
        in_c: 3,
        k_h: 3,
        k_w: 3,
        out_c: 5,
        stride,
        pad,
    };
    let mut rng = Pcg32::seeded(72);
    let x = rand_tensor(&[g.batch, g.in_h, g.in_w, g.in_c], &mut rng);
    let w = rand_tensor(&[g.k_h, g.k_w, g.in_c, g.out_c], &mut rng);
    for mul in &strategies {
        let label = mul.describe();

        // forward: y = im2col(x) * w
        let y = amconv2d::forward(mul, &x, &w, stride, pad);
        let mut cols = vec![0.0f32; g.col_rows() * g.col_cols()];
        im2col_forward(&g, &x.data, &mut cols);
        let mut y_ref = vec![0.0f32; g.col_rows() * g.out_c];
        gemm_scalar_reference(mul, &cols, &w.data, &mut y_ref, g.col_rows(), g.col_cols(), g.out_c);
        assert_eq!(y.data.len(), y_ref.len(), "{label}: forward shape");
        for i in 0..y_ref.len() {
            assert_eq!(y.data[i].to_bits(), y_ref[i].to_bits(), "{label}: forward idx {i}");
        }

        let dy = rand_tensor(&y.shape, &mut Pcg32::seeded(73));

        // weight grad: dw = im2col_wg(x) * dy
        let dw = amconv2d::weight_grad(mul, &x, &dy, &w.shape, stride, pad);
        let q = g.batch * g.out_h() * g.out_w();
        let mut wg_cols = vec![0.0f32; g.col_cols() * q];
        im2col_weight_grad(&g, &x.data, &mut wg_cols);
        let mut dw_ref = vec![0.0f32; g.col_cols() * g.out_c];
        gemm_scalar_reference(mul, &wg_cols, &dy.data, &mut dw_ref, g.col_cols(), q, g.out_c);
        assert_eq!(dw.data.len(), dw_ref.len(), "{label}: dw shape");
        for i in 0..dw_ref.len() {
            assert_eq!(dw.data[i].to_bits(), dw_ref[i].to_bits(), "{label}: dw idx {i}");
        }

        // preceding-layer grad: dx = im2col_plg(dy) * transpose_reverse(w)
        let dx = amconv2d::input_grad(mul, &dy, &w, &x.shape, stride, pad);
        let rows = g.batch * g.in_h * g.in_w;
        let rlen = g.k_h * g.k_w * g.out_c;
        let mut plg_cols = vec![0.0f32; rows * rlen];
        im2col_plg(&g, &dy.data, &mut plg_cols);
        let wrt = transpose_reverse(&w.data, g.k_h, g.k_w, g.in_c, g.out_c);
        let mut dx_ref = vec![0.0f32; rows * g.in_c];
        gemm_scalar_reference(mul, &plg_cols, &wrt, &mut dx_ref, rows, rlen, g.in_c);
        assert_eq!(dx.data.len(), dx_ref.len(), "{label}: dx shape");
        for i in 0..dx_ref.len() {
            assert_eq!(dx.data[i].to_bits(), dx_ref[i].to_bits(), "{label}: dx idx {i}");
        }
    }
}

/// Same bit-identity at a second odd geometry — stride 1 with an even
/// kernel (2x2) on a non-square input — so the tiled path is checked on
/// both strided and unit-stride im2col layouts.
#[test]
fn conv_forward_bitwise_matches_reference_even_kernel() {
    let model = registry::by_name("mit16").unwrap();
    let lut = MantissaLut::generate(model.as_ref());
    let mul = MulKernel::Lut(AmSim::new(&lut));
    let g = Conv2dGeom {
        batch: 3,
        in_h: 5,
        in_w: 11,
        in_c: 2,
        k_h: 2,
        k_w: 2,
        out_c: 4,
        stride: 1,
        pad: 0,
    };
    let mut rng = Pcg32::seeded(74);
    let x = rand_tensor(&[g.batch, g.in_h, g.in_w, g.in_c], &mut rng);
    let w = rand_tensor(&[g.k_h, g.k_w, g.in_c, g.out_c], &mut rng);
    let y = amconv2d::forward(&mul, &x, &w, g.stride, g.pad);
    let mut cols = vec![0.0f32; g.col_rows() * g.col_cols()];
    im2col_forward(&g, &x.data, &mut cols);
    let mut y_ref = vec![0.0f32; g.col_rows() * g.out_c];
    gemm_scalar_reference(&mul, &cols, &w.data, &mut y_ref, g.col_rows(), g.col_cols(), g.out_c);
    for i in 0..y_ref.len() {
        assert_eq!(y.data[i].to_bits(), y_ref[i].to_bits(), "idx {i}");
    }
}
