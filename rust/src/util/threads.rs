//! Data-parallel helpers over `std::thread::scope`.
//!
//! The paper's CUDA kernels get their throughput from fine-grained GPU
//! parallelism; on the CPU substrate the analogous lever is chunked
//! multi-threading. (The benchmark machine for this reproduction exposes a
//! single core, so `available_threads()` frequently returns 1 and these
//! helpers degrade to plain loops — the code path is still exercised by
//! tests with explicit thread counts.)

/// Number of worker threads to use by default.
pub fn available_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f(chunk_index, start, end)` over `n` items split into `threads`
/// contiguous ranges. `f` must be `Sync` since it is shared across threads.
pub fn parallel_ranges<F: Fn(usize, usize, usize) + Sync>(n: usize, threads: usize, f: F) {
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n == 0 {
        f(0, 0, n);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for t in 0..threads {
            let start = t * chunk;
            let end = ((t + 1) * chunk).min(n);
            if start >= end {
                break;
            }
            let f = &f;
            s.spawn(move || f(t, start, end));
        }
    });
}

/// Map `f` over disjoint mutable row-chunks of `out` (each of `row_len`
/// elements). This is the shape of every kernel loop: each output row is
/// written by exactly one thread.
pub fn parallel_rows<F>(out: &mut [f32], row_len: usize, threads: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    assert!(row_len > 0 && out.len() % row_len == 0);
    let rows = out.len() / row_len;
    let threads = threads.max(1).min(rows.max(1));
    if threads <= 1 {
        for (r, chunk) in out.chunks_mut(row_len).enumerate() {
            f(r, chunk);
        }
        return;
    }
    let rows_per = rows.div_ceil(threads);
    std::thread::scope(|s| {
        for (t, block) in out.chunks_mut(rows_per * row_len).enumerate() {
            let f = &f;
            s.spawn(move || {
                for (i, chunk) in block.chunks_mut(row_len).enumerate() {
                    f(t * rows_per + i, chunk);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn ranges_cover_everything_once() {
        for threads in [1, 2, 3, 7] {
            let hits = AtomicUsize::new(0);
            parallel_ranges(100, threads, |_, s, e| {
                hits.fetch_add(e - s, Ordering::SeqCst);
            });
            assert_eq!(hits.load(Ordering::SeqCst), 100, "threads={threads}");
        }
    }

    #[test]
    fn ranges_handle_zero() {
        parallel_ranges(0, 4, |_, s, e| assert_eq!(s, e));
    }

    #[test]
    fn rows_write_disjoint() {
        for threads in [1, 2, 4] {
            let mut out = vec![0.0f32; 12];
            parallel_rows(&mut out, 3, threads, |r, chunk| {
                for c in chunk.iter_mut() {
                    *c = r as f32;
                }
            });
            assert_eq!(out, vec![0., 0., 0., 1., 1., 1., 2., 2., 2., 3., 3., 3.]);
        }
    }
}
