//! # ApproxTrain — fast simulation of approximate FP multipliers for DNN
//! training and inference
//!
//! Rust + JAX + Pallas reproduction of *ApproxTrain* (Gong et al., 2022).
//!
//! The crate is the **Layer-3 coordinator** of a three-layer stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): LUT-based
//!   approximate-FP GEMM/matvec (AMSim, paper Alg. 2) compiled at build time.
//! * **L2** — JAX models (`python/compile/`): `AMCONV2D`/`AMDENSE` layers with
//!   the paper's IM2COL+GEMM restructuring of forward + both backward
//!   gradients, lowered once to HLO text under `artifacts/`.
//! * **L3** — this crate: multiplier functional models, LUT generation
//!   (paper Alg. 1), dataset pipeline, PJRT runtime, training/inference
//!   drivers, a batching inference server, and the experiment harness that
//!   regenerates every table and figure of the paper.
//!
//! Python never runs on the request path: after `make artifacts` the Rust
//! binary is self-contained.
//!
//! ## Quick tour
//!
//! ```no_run
//! use approxtrain::mult::registry;
//! use approxtrain::lut::MantissaLut;
//! use approxtrain::amsim::AmSim;
//!
//! // 1. pick a multiplier functional model (the paper's "C/C++ model")
//! let afm16 = registry::by_name("afm16").unwrap();
//! // 2. tabulate its mantissa products (paper Algorithm 1)
//! let lut = MantissaLut::generate(afm16.as_ref());
//! // 3. simulate (paper Algorithm 2)
//! let sim = AmSim::new(&lut);
//! let c = sim.mul(1.5f32, 2.25f32);
//! assert!((c - 3.375).abs() / 3.375 < 0.05);
//! ```
pub mod amsim;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod hwmodel;
pub mod kernels;
pub mod layers;
pub mod lut;
pub mod mult;
pub mod nn;
pub mod runtime;
pub mod tensor;
pub mod util;
