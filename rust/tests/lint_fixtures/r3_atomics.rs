//! Planted R3 site: an atomic `Ordering::` use. The lint test asserts
//! the site scan finds exactly this line with its whitespace-free key.

use std::sync::atomic::{AtomicUsize, Ordering};

pub fn bump(c: &AtomicUsize) -> usize {
    c.fetch_add(1, Ordering::SeqCst)
}
