"""Approximate-multiplier functional models — bit-exact Python mirrors of
``rust/src/mult/models.rs``. They exist so that

* LUT generation can be cross-checked between the two implementations
  (golden-file tests assert identical binary output), and
* the pure-jnp kernel oracle (``kernels/ref.py``) has a trusted scalar
  reference.

All ``mantissa_product`` functions are vectorized over numpy uint32 arrays
of 23-bit mantissa fields and return ``(carry, mantissa23)`` uint32 arrays.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import numpy as np

from .fp_bits import (EXP_BIAS, EXP_MASK, MANT_BITS, MANT_MASK, SIGN_MASK,
                      compose, decompose, from_bits, to_bits)

# REALM correction constants — identical to rust/src/mult/models.rs
REALM_LOG_CORR = np.array(
    [209403, 506903, 669557, 721940, 682465, 565287, 381522, 140059], dtype=np.int64)
REALM_ANTILOG_CORR = np.array(
    [-152893, -408621, -592590, -698305, -718684, -646004, -471841, -187011], dtype=np.int64)


def _trunc_m(mant, m: int):
    keep = np.uint32((MANT_MASK << (MANT_BITS - m)) & MANT_MASK)
    return np.asarray(mant, dtype=np.uint32) & keep


@dataclass(frozen=True)
class Mult:
    """A multiplier functional model."""
    name: str
    m: int  # mantissa bits
    mantissa_product: Callable  # (ma23, mb23) -> (carry, mant23)

    def mul(self, a, b):
        """Full approximate FP multiply — mirror of ``mul_via_mantissa``."""
        a = np.asarray(a, dtype=np.float32)
        b = np.asarray(b, dtype=np.float32)
        sa, ea, ma = decompose(a)
        sb, eb, mb = decompose(b)
        sign = sa ^ sb
        carry, mant = self.mantissa_product(ma, mb)
        exp = ea.astype(np.int64) + eb.astype(np.int64) - EXP_BIAS
        flush = (exp <= 0) | (ea == 0) | (eb == 0)
        exp_c = exp + carry.astype(np.int64)
        inf = exp_c >= 255
        out = compose(sign, np.clip(exp_c, 1, 254).astype(np.uint32), mant)
        out = np.where(inf, compose(sign, 255, 0), out)
        out = np.where(flush, compose(sign, 0, 0), out)
        # delegate IEEE specials to hardware semantics like the Rust mirror
        special = ~(np.isfinite(a) & np.isfinite(b))
        out = np.where(special, a * b, out)
        return out.astype(np.float32)


def exact_fp(name: str, m: int, rne: bool = True) -> Mult:
    def mantissa_product(ma, mb):
        ma = _trunc_m(ma, m).astype(np.uint64)
        mb = _trunc_m(mb, m).astype(np.uint64)
        hidden = np.uint64(1 << MANT_BITS)
        p = (hidden | ma) * (hidden | mb)  # [2^46, 2^48)
        carry = (p >> np.uint64(47)).astype(np.uint32)
        s = np.where(carry == 1, p >> np.uint64(1), p)
        frac46 = s & np.uint64((1 << 46) - 1)
        drop = 46 - m
        kept = (frac46 >> np.uint64(drop)).astype(np.uint64)
        if rne:
            half = np.uint64(1 << (drop - 1))
            low = frac46 & np.uint64((1 << drop) - 1)
            kept = kept + ((low > half) | ((low == half) & ((kept & 1) == 1)))
        ovf = (kept >> np.uint64(m)) != 0
        kept = np.where(ovf, np.uint64(0), kept)
        carry = carry + ovf.astype(np.uint32)
        return carry, ((kept << np.uint64(MANT_BITS - m)).astype(np.uint32) & MANT_MASK)

    return Mult(name, m, mantissa_product)


def mitchell(name: str, m: int) -> Mult:
    def mantissa_product(ma, mb):
        s = _trunc_m(ma, m).astype(np.uint32) + _trunc_m(mb, m)
        top = np.uint32(1 << MANT_BITS)
        carry = (s >= top).astype(np.uint32)
        frac = np.where(carry == 1, s - top, s)
        return carry, _trunc_m(frac, m)

    return Mult(name, m, mantissa_product)


def afm(name: str, m: int, k: int) -> Mult:
    def mantissa_product(ma, mb):
        ma64 = _trunc_m(ma, m).astype(np.uint64)
        mb64 = _trunc_m(mb, m).astype(np.uint64)
        sh = np.uint64(MANT_BITS - k)
        ha = (ma64 >> sh) << sh
        hb = (mb64 >> sh) << sh
        xy = (ha * hb) >> np.uint64(MANT_BITS)
        comp = (ma64 + mb64) >> np.uint64(k + 1)
        t = ma64 + mb64 + xy + comp
        top = np.uint64(1 << MANT_BITS)
        carry = (t >= top).astype(np.uint32)
        frac = np.where(carry == 1, np.minimum((t - top) >> np.uint64(1),
                                               np.uint64(MANT_MASK)), t)
        return carry, _trunc_m(frac.astype(np.uint32), m)

    return Mult(name, m, mantissa_product)


def realm(name: str, m: int) -> Mult:
    def mantissa_product(ma, mb):
        ma = _trunc_m(ma, m)
        mb = _trunc_m(mb, m)
        seg_a = (ma >> np.uint32(MANT_BITS - 3)).astype(np.int64)
        seg_b = (mb >> np.uint32(MANT_BITS - 3)).astype(np.int64)
        s = (ma.astype(np.int64) + mb.astype(np.int64)
             + REALM_LOG_CORR[seg_a] + REALM_LOG_CORR[seg_b])
        top = np.int64(1 << MANT_BITS)
        carry = (s >= top).astype(np.uint32)
        s = np.where(carry == 1, s - top, s)
        f = np.clip(s, 0, int(MANT_MASK))
        seg_f = (f >> np.int64(MANT_BITS - 3)).astype(np.int64)
        g = np.clip(f + REALM_ANTILOG_CORR[seg_f], 0, int(MANT_MASK))
        return carry, _trunc_m(g.astype(np.uint32), m)

    return Mult(name, m, mantissa_product)


def and_comp(name: str, m: int) -> Mult:
    def mantissa_product(ma, mb):
        ma64 = _trunc_m(ma, m).astype(np.uint64)
        mb64 = _trunc_m(mb, m).astype(np.uint64)
        t = ma64 + mb64 + (ma64 & mb64)
        top = np.uint64(1 << MANT_BITS)
        carry = (t >= top).astype(np.uint32)
        frac = np.where(carry == 1, np.minimum((t - top) >> np.uint64(1),
                                               np.uint64(MANT_MASK)), t)
        return carry, _trunc_m(frac.astype(np.uint32), m)

    return Mult(name, m, mantissa_product)


def by_name(name: str) -> Mult:
    """Mirror of ``rust::mult::registry::by_name``."""
    reg = {
        "fp32": lambda: exact_fp("fp32", 23, True),
        "bfloat16": lambda: exact_fp("bfloat16", 7, True),
        "fp16": lambda: exact_fp("fp16", 10, True),
        "afm32": lambda: afm("afm32", 23, 6),
        "afm16": lambda: afm("afm16", 7, 4),
        "mit16": lambda: mitchell("mit16", 7),
        "realm16": lambda: realm("realm16", 7),
        "trunc16": lambda: exact_fp("trunc16", 7, False),
        "comp16": lambda: and_comp("comp16", 7),
    }
    if name not in reg:
        raise KeyError(f"unknown multiplier {name!r}")
    return reg[name]()


NAMES = ["fp32", "bfloat16", "fp16", "afm32", "afm16", "mit16", "realm16",
         "trunc16", "comp16"]
LUT_ABLE = [n for n in NAMES if by_name(n).m <= 12]
