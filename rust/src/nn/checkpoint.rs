//! Checkpoints: named f32 tensors in a simple binary container (magic +
//! count + per-tensor name/shape/payload + crc). Used for the cross-format
//! experiment (Table IV: train with one multiplier, evaluate with another)
//! and the pruning flow (Fig 11: load a pre-trained model, prune, retrain).

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::lut::format::crc32;

pub const MAGIC: &[u8; 8] = b"ATCKPT\x01\0";

/// An ordered set of named tensors.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Checkpoint {
    pub tensors: BTreeMap<String, (Vec<usize>, Vec<f32>)>,
}

impl Checkpoint {
    pub fn insert(&mut self, name: &str, shape: &[usize], data: Vec<f32>) {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        self.tensors.insert(name.to_string(), (shape.to_vec(), data));
    }

    pub fn get(&self, name: &str) -> Option<&(Vec<usize>, Vec<f32>)> {
        self.tensors.get(name)
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let mut body = Vec::new();
        body.extend_from_slice(&(self.tensors.len() as u32).to_le_bytes());
        for (name, (shape, data)) in &self.tensors {
            let nb = name.as_bytes();
            body.extend_from_slice(&(nb.len() as u32).to_le_bytes());
            body.extend_from_slice(nb);
            body.extend_from_slice(&(shape.len() as u32).to_le_bytes());
            for &d in shape {
                body.extend_from_slice(&(d as u64).to_le_bytes());
            }
            body.extend_from_slice(&(data.len() as u64).to_le_bytes());
            for &v in data {
                body.extend_from_slice(&v.to_le_bytes());
            }
        }
        let mut out = Vec::with_capacity(body.len() + 16);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&crc32(&body).to_le_bytes());
        out.extend_from_slice(&body);
        out
    }

    pub fn from_bytes(data: &[u8]) -> Result<Checkpoint> {
        if data.len() < 12 || &data[0..8] != MAGIC {
            bail!("not a checkpoint file");
        }
        let want_crc = u32::from_le_bytes(data[8..12].try_into().unwrap());
        let body = &data[12..];
        if crc32(body) != want_crc {
            bail!("checkpoint payload corrupt");
        }
        let mut pos = 0usize;
        let rd_u32 = |pos: &mut usize| -> Result<u32> {
            if *pos + 4 > body.len() {
                bail!("truncated checkpoint");
            }
            let v = u32::from_le_bytes(body[*pos..*pos + 4].try_into().unwrap());
            *pos += 4;
            Ok(v)
        };
        let rd_u64 = |pos: &mut usize| -> Result<u64> {
            if *pos + 8 > body.len() {
                bail!("truncated checkpoint");
            }
            let v = u64::from_le_bytes(body[*pos..*pos + 8].try_into().unwrap());
            *pos += 8;
            Ok(v)
        };
        let count = rd_u32(&mut pos)?;
        let mut ckpt = Checkpoint::default();
        for _ in 0..count {
            let nlen = rd_u32(&mut pos)? as usize;
            let name = std::str::from_utf8(&body[pos..pos + nlen])
                .context("bad tensor name")?
                .to_string();
            pos += nlen;
            let rank = rd_u32(&mut pos)? as usize;
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(rd_u64(&mut pos)? as usize);
            }
            let n = rd_u64(&mut pos)? as usize;
            if pos + 4 * n > body.len() {
                bail!("truncated tensor {name}");
            }
            let data: Vec<f32> = body[pos..pos + 4 * n]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            pos += 4 * n;
            ckpt.tensors.insert(name, (shape, data));
        }
        Ok(ckpt)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::File::create(path)?.write_all(&self.to_bytes())?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut data = Vec::new();
        std::fs::File::open(path)
            .with_context(|| format!("opening checkpoint {}", path.display()))?
            .read_to_end(&mut data)?;
        Self::from_bytes(&data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut c = Checkpoint::default();
        c.insert("fc1/w", &[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        c.insert("fc1/b", &[3], vec![0.1, 0.2, 0.3]);
        let back = Checkpoint::from_bytes(&c.to_bytes()).unwrap();
        assert_eq!(back, c);
        assert_eq!(back.get("fc1/w").unwrap().0, vec![2, 3]);
    }

    #[test]
    fn corruption_detected() {
        let mut c = Checkpoint::default();
        c.insert("w", &[2], vec![1.0, 2.0]);
        let mut bytes = c.to_bytes();
        let n = bytes.len();
        bytes[n - 2] ^= 0xFF;
        assert!(Checkpoint::from_bytes(&bytes).is_err());
        assert!(Checkpoint::from_bytes(b"junk").is_err());
    }

    #[test]
    fn file_roundtrip() {
        let mut c = Checkpoint::default();
        c.insert("x", &[1], vec![42.0]);
        let path = std::env::temp_dir().join("approxtrain_ckpt_test/a.ckpt");
        c.save(&path).unwrap();
        assert_eq!(Checkpoint::load(&path).unwrap(), c);
    }
}
