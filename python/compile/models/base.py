"""Model abstraction shared by LeNet and ResNet definitions.

A model is a list of named parameter specs plus an ``apply`` function.
Parameter *initialization metadata* (init kind + fan-in) is exported into
the artifact manifest so the Rust coordinator can initialize weights
without any knowledge of the model internals — the same split the paper
has between TensorFlow variable initializers and the CUDA kernels.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Tuple


@dataclass(frozen=True)
class ParamSpec:
    name: str
    shape: Tuple[int, ...]
    init: str  # he_normal | zeros | ones
    fan_in: int = 0


@dataclass
class Model:
    name: str
    input_shape: Tuple[int, ...]  # (h, w, c)
    classes: int
    params: List[ParamSpec] = field(default_factory=list)
    # apply(cfg, params_dict, x, lut) -> logits
    apply: Callable = None

    def param_dict_template(self):
        return {p.name: p for p in self.params}


def conv_spec(name: str, kh: int, kw: int, c: int, oc: int) -> ParamSpec:
    return ParamSpec(name, (kh, kw, c, oc), "he_normal", fan_in=kh * kw * c)


def dense_specs(name: str, n_in: int, n_out: int) -> List[ParamSpec]:
    return [
        ParamSpec(f"{name}/w", (n_in, n_out), "he_normal", fan_in=n_in),
        ParamSpec(f"{name}/b", (n_out,), "zeros"),
    ]


def bn_specs(name: str, c: int) -> List[ParamSpec]:
    return [
        ParamSpec(f"{name}/gamma", (1, 1, 1, c), "ones"),
        ParamSpec(f"{name}/beta", (1, 1, 1, c), "zeros"),
    ]
