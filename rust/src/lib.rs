//! # ApproxTrain — fast simulation of approximate FP multipliers for DNN
//! training and inference
//!
//! Rust + JAX + Pallas reproduction of *ApproxTrain* (Gong et al., 2022).
//!
//! The crate is the **Layer-3 coordinator** of a three-layer stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): LUT-based
//!   approximate-FP GEMM/matvec (AMSim, paper Alg. 2) compiled at build time.
//! * **L2** — JAX models (`python/compile/`): `AMCONV2D`/`AMDENSE` layers with
//!   the paper's IM2COL+GEMM restructuring of forward + both backward
//!   gradients, lowered once to HLO text under `artifacts/`.
//! * **L3** — this crate: multiplier functional models, LUT generation
//!   (paper Alg. 1), dataset pipeline, PJRT runtime, training/inference
//!   drivers, a multi-lane batching inference server with backpressure
//!   over pluggable backends (compiled artifacts or the pure-Rust
//!   executors), and the experiment harness that regenerates every table
//!   and figure of the paper.
//!
//! Python never runs on the request path: after `make artifacts` the Rust
//! binary is self-contained.
//!
//! ## Simulation strategies (paper Fig. 6)
//!
//! Every CPU kernel multiply is routed through a
//! [`kernels::MulKernel`], whose three variants are the paper's Fig. 6
//! configurations:
//!
//! | variant | paper system | what each multiply costs |
//! |---|---|---|
//! | [`kernels::MulKernel::Native`] | ATnG / TFnG | the hardware `*` (baseline) |
//! | [`kernels::MulKernel::Direct`] | ATxC "direct C simulation" | a functional-model call (bit manipulation) |
//! | [`kernels::MulKernel::Lut`]    | ATxG AMSim | one mantissa-LUT gather (Alg. 2) |
//!
//! The kernels consume these through the batched
//! [`kernels::MulBackend`] panel operations (`mul_panel` / `dot_panel` /
//! `dot_panel_acc` / `fma_row` / `mul_microtile`): strategy dispatch is
//! paid once per contiguous panel, so the AMSim path is a tight
//! LUT-gather loop with hoisted shift/mask and the native path a plain
//! FMA loop. The GEMM hot path is the hierarchical cache-blocked tiled
//! kernel ([`kernels::gemm::gemm_tiled`]): packed `A` row-panels / `B`
//! column-panels (`NR`-strip interleaved) in reusable per-thread
//! buffers, 2D output tiles scheduled work-stealing over the persistent
//! worker pool in [`util::threads`], each tile drained by the
//! register-blocked `MR x NR` micro-kernel
//! ([`kernels::MulBackend::mul_microtile`]: operands decomposed once per
//! contraction step, `MR*NR` independent FP32 accumulator chains).
//! Packing is generalized over
//! [`kernels::gemm::PackA`]/[`kernels::gemm::PackB`] panel sources
//! ([`kernels::gemm::gemm_tiled_src`]), which is how the conv layer runs
//! its three GEMMs *implicitly* — panels packed straight from the NHWC
//! tensors through the fused im2col indexing, no cols matrix ever
//! materialized. The micro-kernel's inner loops carry
//! runtime-feature-detected SIMD arms ([`util::simd::SimdLevel`],
//! capped by the `APPROXTRAIN_SIMD` env knob): on AVX2 machines the LUT
//! drain gathers 8 mantissa products per `vpgatherdd` with vectorized
//! sign/exponent/mantissa decomposition (`amsim/simd.rs`), the native
//! baseline gets vector multiply / FMA arms (`kernels/simd.rs`), and
//! the lanes run *across* the micro-tile's independent accumulator
//! chains so the contract below is untouched — the scalar body stays
//! the everywhere-fallback and the oracle. One accumulation contract
//! (running FP32 accumulator,
//! ascending contraction order) keeps every path bit-identical to the
//! per-element scalar oracle at any tile/micro-tile geometry, thread
//! count and SIMD level (enforced by `tests/batched_vs_scalar.rs`,
//! `tests/microtile.rs`, `tests/conv_grads.rs`,
//! `tests/golden_mults.rs` and the `tests/simd_lanes.rs`
//! lane-differential net). `cargo bench -- gemm` (or `approxtrain
//! bench-gemm`) times all strategies, panel vs tiled, the micro-kernel
//! vs per-element-drain ablation, per-SIMD-level rows with the
//! feature-detection record, plus an autotune probe sweeping
//! `MR x NR` alongside the tile shape, and records `BENCH_gemm.json`
//! (schema v5); `cargo bench -- conv` (or `approxtrain bench-conv`)
//! records the implicit-vs-materialized conv comparison into
//! `BENCH_conv.json`; methodology in `docs/BENCHMARKS.md`.
//!
//! ## Module map (`rust/src/`)
//!
//! ```text
//! mult/        multiplier functional models (paper's "C/C++ models") + FP32 bit plumbing
//! lut/         mantissa-product LUT generation (Algorithm 1) + binary format
//! amsim/       LUT-based multiplication simulator (Algorithm 2) + batched panels
//!              (+ simd.rs: the AVX2 vpgatherdd LUT arm)
//! kernels/     CPU analogs of the paper's CUDA kernels: GEMM, IM2COL x3,
//!              transpose-reverse, matvec, pooling (§VI)
//!              (+ simd.rs: the native baseline's AVX2/FMA arms)
//! layers/      AMCONV2D / AMDENSE / activations / softmax / batchnorm (§VI-B, §VI-C)
//! nn/          pure-Rust LeNet/ResNet executors, init, metrics, checkpoints
//! tensor/      minimal row-major tensor
//! data/        IDX loader + deterministic synthetic datasets
//! runtime/     PJRT engine for the compiled artifacts (stubbed offline)
//! coordinator/ trainer, multi-lane batching inference server over
//!              pluggable InferBackends, the fault-tolerant networked
//!              serving tier (wire protocol, deadlines, priority load
//!              shedding, fault injection), deterministic data-parallel
//!              training (fixed-order gradient reduction tree),
//!              experiments, pruning, reports
//! hwmodel/     Fig. 1 area/power efficiency model
//! util/        RNG, JSON, stats, timer, persistent thread pool, prop-test
//!              harness, SIMD capability detection (simd::SimdLevel +
//!              the APPROXTRAIN_SIMD knob)
//! lint/        approxlint: the in-repo static-analysis pass (SAFETY
//!              comments, determinism bans, audited atomics and
//!              accumulation shapes, condvar/lock discipline, paired
//!              SIMD gates, registration cross-checks; docs/LINTS.md)
//! cli/         argument parsing for the `approxtrain` binary
//! ```
//!
//! ## Quick tour
//!
//! ```no_run
//! use approxtrain::mult::registry;
//! use approxtrain::lut::MantissaLut;
//! use approxtrain::amsim::AmSim;
//!
//! // 1. pick a multiplier functional model (the paper's "C/C++ model")
//! let afm16 = registry::by_name("afm16").unwrap();
//! // 2. tabulate its mantissa products (paper Algorithm 1)
//! let lut = MantissaLut::generate(afm16.as_ref());
//! // 3. simulate (paper Algorithm 2)
//! let sim = AmSim::new(&lut);
//! let c = sim.mul(1.5f32, 2.25f32);
//! assert!((c - 3.375).abs() / 3.375 < 0.05);
//! ```
pub mod amsim;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod hwmodel;
pub mod kernels;
pub mod layers;
pub mod lint;
pub mod lut;
pub mod mult;
pub mod nn;
pub mod runtime;
pub mod tensor;
pub mod util;
