//! Networked serving tier: a fault-tolerant TCP front over the lane
//! server, pure `std` (no tokio — `std::net::TcpListener` + threads).
//!
//! Topology per server:
//!
//! * one **accept** thread (non-blocking listener, poll tick);
//! * per connection, one **reader** thread (interruptible frame reads,
//!   admission) and one **writer** thread (serializes replies from an
//!   mpsc so lanes never block on a slow client socket);
//! * per tenant, a bounded **priority admission queue** and N **lane**
//!   threads, each owning a [`CpuBackend`] replica and running the same
//!   dynamic-batching / cycle-padding policy as the in-process
//!   [`super::server`] lanes — which is why every accepted networked
//!   reply is bit-identical to [`super::server::serve_on_caller`].
//!
//! Robustness invariants (each one is forced by `rust/tests/serve_net.rs`
//! through the [`super::faults`] injection registry):
//!
//! * **Deadlines** ride the wire as relative budgets and are enforced at
//!   admission, at queue pop, and again after compute — an expired
//!   request gets a typed `DeadlineExceeded`, never a silent stale reply.
//! * **Load shedding** at admission is priority-aware: `Low` is shed at
//!   half depth, `Normal` at 3/4 depth, `High` only overflows at full
//!   depth. Every shed is counted exactly, per class.
//! * **Exactly-once replies**: a [`Responder`] guards every request; if
//!   any path drops it unanswered (lane kill, drain timeout), its `Drop`
//!   emits a typed `Stopped` frame — a waiting client can never hang.
//! * **Fail-stop**: a lane error fails the tenant's queue; queued
//!   requests are drained with typed errors, never silently discarded.
//! * **LUT hot-swap behind an epoch**: [`NetHandle::swap_mul`] mutates a
//!   tenant's template backend under its lock and bumps the epoch; lanes
//!   re-clone the whole template when they observe a new epoch, so no
//!   request ever runs on a half-swapped table. Replies carry the epoch
//!   that computed them.
//! * **Graceful drain**: shutdown stops accepting, lets lanes finish
//!   what was admitted within a drain deadline, then fail-stops the
//!   remainder (typed errors, exact drop accounting).

use std::collections::{BTreeMap, VecDeque};
use std::io::Read;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use super::backend::{CpuBackend, InferBackend, MulSpec};
use super::faults::FaultPlan;
use super::server::{InferError, ServeConfig, Stats};
use super::wire::{self, FrameKind, Priority, RequestFrame, ResponseFrame, Status, WireError};

// ---------------------------------------------------------------------------
// Config / policy
// ---------------------------------------------------------------------------

/// Networked-tier knobs on top of the per-lane [`ServeConfig`].
#[derive(Clone, Copy, Debug)]
pub struct NetConfig {
    /// per-lane batching window + admission queue depth
    pub serve: ServeConfig,
    /// graceful-drain budget at shutdown: admitted work gets this long to
    /// finish before the remainder is fail-stopped with typed errors
    pub drain_deadline: Duration,
    /// reader/acceptor poll tick (stop-flag latency; not a data-path cost)
    pub poll: Duration,
}

impl Default for NetConfig {
    fn default() -> NetConfig {
        NetConfig {
            serve: ServeConfig::default(),
            drain_deadline: Duration::from_secs(2),
            poll: Duration::from_millis(2),
        }
    }
}

/// SLO-aware admission limit: the queue occupancy at (or above) which a
/// class is turned away. Low is shed first (half depth), Normal at 3/4,
/// High only at the hard depth (= overflow). Monotone in priority, so
/// under pressure capacity is always spent on the most important work.
pub fn admission_limit(depth: usize, prio: Priority) -> usize {
    match prio {
        Priority::High => depth,
        Priority::Normal => (depth * 3 / 4).max(1),
        Priority::Low => (depth / 2).max(1),
    }
}

// ---------------------------------------------------------------------------
// Exact failure accounting
// ---------------------------------------------------------------------------

#[derive(Default)]
struct NetCounters {
    accepted: AtomicU64,
    replied_ok: AtomicU64,
    /// sheds by priority index (High/Normal/Low); High stays 0 by
    /// construction (its limit is the hard depth → Overflow instead)
    shed: [AtomicU64; 3],
    overflow: AtomicU64,
    expired_admission: AtomicU64,
    expired_queue: AtomicU64,
    expired_reply: AtomicU64,
    quota_rejected: AtomicU64,
    unknown_tenant: AtomicU64,
    malformed: AtomicU64,
    connections: AtomicU64,
    disconnects_midframe: AtomicU64,
    draining_rejected: AtomicU64,
    stopped_replies: AtomicU64,
    lut_swaps: AtomicU64,
    drain_dropped: AtomicU64,
}

/// Plain snapshot of the server's exact failure accounting. Every
/// admission outcome increments exactly one counter, so
/// `accepted + shed + overflow + expired_admission + quota_rejected +
/// unknown_tenant + draining_rejected (+ malformed)` equals the requests
/// offered — the `serve_net` suite asserts this bookkeeping exactly.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct NetCounts {
    pub accepted: u64,
    pub replied_ok: u64,
    pub shed: [u64; 3],
    pub overflow: u64,
    pub expired_admission: u64,
    pub expired_queue: u64,
    pub expired_reply: u64,
    pub quota_rejected: u64,
    pub unknown_tenant: u64,
    pub malformed: u64,
    pub connections: u64,
    pub disconnects_midframe: u64,
    pub draining_rejected: u64,
    pub stopped_replies: u64,
    pub lut_swaps: u64,
    pub drain_dropped: u64,
}

impl NetCounts {
    pub fn shed_total(&self) -> u64 {
        self.shed.iter().sum()
    }

    pub fn deadline_expired_total(&self) -> u64 {
        self.expired_admission + self.expired_queue + self.expired_reply
    }
}

impl NetCounters {
    fn snapshot(&self) -> NetCounts {
        let ld = Ordering::Relaxed;
        NetCounts {
            accepted: self.accepted.load(ld),
            replied_ok: self.replied_ok.load(ld),
            shed: [self.shed[0].load(ld), self.shed[1].load(ld), self.shed[2].load(ld)],
            overflow: self.overflow.load(ld),
            expired_admission: self.expired_admission.load(ld),
            expired_queue: self.expired_queue.load(ld),
            expired_reply: self.expired_reply.load(ld),
            quota_rejected: self.quota_rejected.load(ld),
            unknown_tenant: self.unknown_tenant.load(ld),
            malformed: self.malformed.load(ld),
            connections: self.connections.load(ld),
            disconnects_midframe: self.disconnects_midframe.load(ld),
            draining_rejected: self.draining_rejected.load(ld),
            stopped_replies: self.stopped_replies.load(ld),
            lut_swaps: self.lut_swaps.load(ld),
            drain_dropped: self.drain_dropped.load(ld),
        }
    }
}

// ---------------------------------------------------------------------------
// Responder — exactly-once typed replies
// ---------------------------------------------------------------------------

/// Holds a slot in a tenant's outstanding-request quota; released on drop
/// (i.e. when the request has been answered, whatever the outcome).
struct QuotaGuard(Arc<AtomicUsize>);

impl Drop for QuotaGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::AcqRel);
    }
}

/// Exactly-once reply guard. Every request — admitted or rejected — owns
/// one; `send` consumes the single reply, and dropping an unanswered
/// responder (lane kill, drain timeout, internal error) emits a typed
/// `Stopped` frame so no client ever hangs on a silently dropped request.
struct Responder {
    id: u64,
    tx: Sender<ResponseFrame>,
    counters: Arc<NetCounters>,
    /// released (on drop) only after the reply is out
    quota: Option<QuotaGuard>,
    done: bool,
}

impl Responder {
    fn new(id: u64, tx: Sender<ResponseFrame>, counters: Arc<NetCounters>) -> Responder {
        Responder { id, tx, counters, quota: None, done: false }
    }

    fn send(&mut self, status: Status, epoch: u64, logits: Vec<f32>, message: String) {
        self.done = true;
        // a dead connection just means nobody is listening; the writer
        // thread cleans up
        let _ = self.tx.send(ResponseFrame { id: self.id, status, epoch, logits, message });
        self.quota = None;
    }
}

impl Drop for Responder {
    fn drop(&mut self) {
        if !self.done {
            self.counters.stopped_replies.fetch_add(1, Ordering::Relaxed);
            let _ = self.tx.send(ResponseFrame {
                id: self.id,
                status: Status::Stopped,
                epoch: 0,
                logits: Vec::new(),
                message: "server stopped before replying".into(),
            });
        }
    }
}

// ---------------------------------------------------------------------------
// Bounded priority admission queue
// ---------------------------------------------------------------------------

struct NetRequest {
    image: Vec<f32>,
    priority: Priority,
    deadline: Option<Instant>,
    submitted: Instant,
    responder: Responder,
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum QueueMode {
    Open,
    /// graceful drain: no admission, lanes finish what is queued
    Draining,
    /// fail-stop: no admission, queued requests already answered
    Failed,
}

struct NqState {
    /// one FIFO per class, popped highest-priority-first
    lanes: [VecDeque<NetRequest>; 3],
    mode: QueueMode,
}

/// Bounded MPMC priority queue: readers submit (shed/overflow at the
/// class admission limits), lanes pop dynamic batches highest-priority
/// first. Same `Condvar` topology as the in-process `AdmissionQueue`.
struct NetQueue {
    depth: usize,
    state: Mutex<NqState>,
    cv: Condvar,
}

impl NetQueue {
    fn new(depth: usize) -> NetQueue {
        assert!(depth > 0, "queue depth must be positive");
        NetQueue {
            depth,
            state: Mutex::new(NqState {
                lanes: [VecDeque::new(), VecDeque::new(), VecDeque::new()],
                mode: QueueMode::Open,
            }),
            cv: Condvar::new(),
        }
    }

    /// Admit or turn away. On rejection the request is handed back with
    /// the typed status so the caller can answer it (and count it).
    fn submit(&self, req: NetRequest) -> Result<(), (NetRequest, Status)> {
        let mut st = self.state.lock().unwrap();
        match st.mode {
            QueueMode::Open => {}
            QueueMode::Draining => return Err((req, Status::Draining)),
            QueueMode::Failed => return Err((req, Status::Stopped)),
        }
        let occupancy: usize = st.lanes.iter().map(|q| q.len()).sum();
        if occupancy >= admission_limit(self.depth, req.priority) {
            let status = if req.priority == Priority::High { Status::Overflow } else { Status::Shed };
            return Err((req, status));
        }
        st.lanes[req.priority.as_u8() as usize].push_back(req);
        drop(st);
        self.cv.notify_one();
        Ok(())
    }

    fn pop_one(st: &mut NqState) -> Option<NetRequest> {
        st.lanes.iter_mut().find_map(|q| q.pop_front())
    }

    /// Lane side: block for the first request, then fill up to `batch`
    /// for at most `max_wait`, always taking the highest class first.
    /// `None` when the queue is closed and drained.
    fn pop_batch(&self, batch: usize, max_wait: Duration) -> Option<Vec<NetRequest>> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(first) = Self::pop_one(&mut st) {
                let mut pending = vec![first];
                let deadline = Instant::now() + max_wait;
                while pending.len() < batch {
                    if let Some(r) = Self::pop_one(&mut st) {
                        pending.push(r);
                        continue;
                    }
                    if st.mode != QueueMode::Open {
                        break;
                    }
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    let (guard, timeout) = self.cv.wait_timeout(st, deadline - now).unwrap();
                    st = guard;
                    if timeout.timed_out() {
                        while pending.len() < batch {
                            match Self::pop_one(&mut st) {
                                Some(r) => pending.push(r),
                                None => break,
                            }
                        }
                        break;
                    }
                }
                if st.lanes.iter().any(|q| !q.is_empty()) {
                    self.cv.notify_one();
                }
                return Some(pending);
            }
            if st.mode != QueueMode::Open {
                return None;
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Stop admitting; lanes drain what is queued and exit.
    fn drain_close(&self) {
        let mut st = self.state.lock().unwrap();
        if st.mode == QueueMode::Open {
            st.mode = QueueMode::Draining;
        }
        drop(st);
        self.cv.notify_all();
    }

    /// Fail-stop: close and answer everything still queued with a typed
    /// `Stopped` (via each responder's drop). Returns how many were
    /// dropped unserved.
    fn fail(&self) -> usize {
        let mut st = self.state.lock().unwrap();
        st.mode = QueueMode::Failed;
        let n: usize = st.lanes.iter().map(|q| q.len()).sum();
        for q in st.lanes.iter_mut() {
            q.clear(); // Responder::drop sends the typed Stopped reply
        }
        drop(st);
        self.cv.notify_all();
        n
    }
}

// ---------------------------------------------------------------------------
// Tenant registry
// ---------------------------------------------------------------------------

/// Per-tenant serving policy.
#[derive(Clone, Copy, Debug)]
pub struct TenantSpec {
    /// lane (backend replica) count
    pub lanes: usize,
    /// max outstanding admitted requests (queued + in compute) for this
    /// tenant; 0 = unlimited
    pub quota: usize,
}

impl Default for TenantSpec {
    fn default() -> TenantSpec {
        TenantSpec { lanes: 1, quota: 0 }
    }
}

/// What the server is built from: tenant name → template backend + spec.
/// The template's weights and [`MulSpec`] define epoch 1; hot-swaps
/// mutate the template and bump the epoch.
#[derive(Default)]
pub struct NetRegistry {
    entries: Vec<(String, CpuBackend, TenantSpec)>,
}

impl NetRegistry {
    pub fn new() -> NetRegistry {
        NetRegistry::default()
    }

    pub fn add(&mut self, tenant: &str, backend: CpuBackend, spec: TenantSpec) -> Result<()> {
        if tenant.is_empty() || tenant.len() > wire::MAX_TENANT_LEN {
            bail!("tenant name must be 1..={} bytes", wire::MAX_TENANT_LEN);
        }
        if self.entries.iter().any(|(n, _, _)| n == tenant) {
            bail!("tenant {tenant:?} registered twice");
        }
        if spec.lanes == 0 {
            bail!("tenant {tenant:?} needs at least one lane");
        }
        self.entries.push((tenant.to_string(), backend, spec));
        Ok(())
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

struct TenantModel {
    epoch: u64,
    backend: CpuBackend,
}

struct TenantState {
    name: String,
    batch: usize,
    image_elems: usize,
    classes: usize,
    quota: usize,
    outstanding: Arc<AtomicUsize>,
    queue: NetQueue,
    /// the swap-able template; lanes clone it under this lock
    template: Mutex<TenantModel>,
    /// lock-free epoch mirror lanes poll between batches
    epoch: AtomicU64,
}

// ---------------------------------------------------------------------------
// Lane loop
// ---------------------------------------------------------------------------

fn net_lane(
    t: Arc<TenantState>,
    counters: Arc<NetCounters>,
    faults: FaultPlan,
    lane: usize,
    max_wait: Duration,
) -> Result<Stats> {
    // replica cloned from the template under its lock (epoch pinned with it)
    let (mut epoch, mut backend) = {
        let tm = t.template.lock().unwrap();
        (tm.epoch, tm.backend.clone())
    };
    let (batch, image_elems, classes) = (t.batch, t.image_elems, t.classes);
    let mut stats = Stats::default();
    let mut images: Vec<f32> = Vec::with_capacity(batch * image_elems);
    let mut batch_index: u64 = 0;
    while let Some(pending) = t.queue.pop_batch(batch, max_wait) {
        // in-queue deadline enforcement: an expired request is answered
        // with the typed error and never computed
        let now = Instant::now();
        let mut live: Vec<NetRequest> = Vec::with_capacity(pending.len());
        for mut r in pending {
            if r.deadline.map_or(false, |d| now >= d) {
                counters.expired_queue.fetch_add(1, Ordering::Relaxed);
                r.responder.send(
                    Status::DeadlineExceeded,
                    0,
                    Vec::new(),
                    "deadline expired in queue".into(),
                );
            } else {
                live.push(r);
            }
        }
        if live.is_empty() {
            continue;
        }
        // scripted faults: delay models a slow lane; kill errors out here
        // — after the pop, so the fail-stop path must answer `live` (it
        // does: dropping them fires each Responder's typed Stopped)
        faults.before_batch(&t.name, lane, batch_index)?;
        batch_index += 1;
        // hot-swap: if the template epoch moved, re-clone the whole
        // template under its lock — a half-swapped table is unobservable
        if t.epoch.load(Ordering::Acquire) != epoch {
            let tm = t.template.lock().unwrap();
            epoch = tm.epoch;
            backend = tm.backend.clone();
        }
        let fill = live.len();
        images.clear();
        for r in &live {
            images.extend_from_slice(&r.image);
        }
        crate::data::pad_batch_by_cycling(&mut images, fill, batch, image_elems);
        let logits = backend.run_batch(&images)?;
        if logits.len() != batch * classes {
            bail!(
                "{}: backend returned {} logits, expected {}",
                backend.describe(),
                logits.len(),
                batch * classes
            );
        }
        let now = Instant::now();
        for (i, mut r) in live.into_iter().enumerate() {
            if r.deadline.map_or(false, |d| now >= d) {
                // computed, but too late: the typed error, never the
                // stale logits
                counters.expired_reply.fetch_add(1, Ordering::Relaxed);
                r.responder.send(
                    Status::DeadlineExceeded,
                    epoch,
                    Vec::new(),
                    "deadline expired before reply".into(),
                );
                continue;
            }
            let latency = r.submitted.elapsed();
            stats.record_request(latency.as_secs_f64());
            counters.replied_ok.fetch_add(1, Ordering::Relaxed);
            r.responder.send(
                Status::Ok,
                epoch,
                logits[i * classes..(i + 1) * classes].to_vec(),
                String::new(),
            );
        }
        stats.record_batch(fill);
    }
    Ok(stats)
}

// ---------------------------------------------------------------------------
// Connection handling
// ---------------------------------------------------------------------------

enum ReadOutcome {
    Done,
    /// EOF at a frame boundary — the peer closed cleanly
    CleanClose,
    /// EOF (or fatal io error) inside a frame — a torn peer
    Torn,
    /// server stopping, observed at a frame boundary
    Stopped,
}

/// Fill `buf` from a read-timeout socket, tolerating `WouldBlock` ticks.
/// The stop flag is honored only at a frame *boundary* (`mid_frame =
/// false`, nothing read yet); mid-frame it grants a bounded grace so
/// in-flight frames finish during drain, then tears.
fn read_full(
    s: &mut TcpStream,
    buf: &mut [u8],
    stop: &AtomicBool,
    mid_frame: bool,
    poll_ticks_grace: u32,
) -> ReadOutcome {
    let mut filled = 0usize;
    let mut grace = 0u32;
    while filled < buf.len() {
        match s.read(&mut buf[filled..]) {
            Ok(0) => {
                return if filled == 0 && !mid_frame {
                    ReadOutcome::CleanClose
                } else {
                    ReadOutcome::Torn
                };
            }
            Ok(n) => filled += n,
            Err(e) if matches!(e.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut) => {
                if stop.load(Ordering::Relaxed) {
                    if filled == 0 && !mid_frame {
                        return ReadOutcome::Stopped;
                    }
                    grace += 1;
                    if grace > poll_ticks_grace {
                        return ReadOutcome::Torn;
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => return ReadOutcome::Torn,
        }
    }
    ReadOutcome::Done
}

fn bad_request_reply(id: u64, err: &WireError) -> ResponseFrame {
    ResponseFrame {
        id,
        status: Status::BadRequest,
        epoch: 0,
        logits: Vec::new(),
        message: err.to_string(),
    }
}

/// Admission: tenant lookup → shape check → quota → deadline stamp (the
/// fault hook can burn budget here) → priority queue submit. Every
/// outcome is a typed reply and exactly one counter bump.
fn admit(
    req: RequestFrame,
    tenants: &BTreeMap<String, Arc<TenantState>>,
    counters: &Arc<NetCounters>,
    faults: &FaultPlan,
    tx: &Sender<ResponseFrame>,
) {
    let arrival = Instant::now();
    let mut responder = Responder::new(req.id, tx.clone(), Arc::clone(counters));
    let Some(t) = tenants.get(&req.tenant) else {
        counters.unknown_tenant.fetch_add(1, Ordering::Relaxed);
        responder.send(
            Status::UnknownTenant,
            0,
            Vec::new(),
            format!("unknown tenant {:?}", req.tenant),
        );
        return;
    };
    if req.image.len() != t.image_elems {
        counters.malformed.fetch_add(1, Ordering::Relaxed);
        responder.send(
            Status::BadRequest,
            0,
            Vec::new(),
            format!("image carries {} f32s, tenant expects {}", req.image.len(), t.image_elems),
        );
        return;
    }
    if t.quota > 0 {
        let prev = t.outstanding.fetch_add(1, Ordering::AcqRel);
        if prev >= t.quota {
            t.outstanding.fetch_sub(1, Ordering::AcqRel);
            counters.quota_rejected.fetch_add(1, Ordering::Relaxed);
            responder.send(
                Status::QuotaExceeded,
                0,
                Vec::new(),
                format!("tenant {:?} at quota {}", req.tenant, t.quota),
            );
            return;
        }
        responder.quota = Some(QuotaGuard(Arc::clone(&t.outstanding)));
    }
    // injected admission delay burns the deadline budget server-side
    faults.on_admission(&req.tenant);
    let deadline = (req.deadline_ms > 0)
        .then(|| arrival + Duration::from_millis(req.deadline_ms as u64));
    if deadline.map_or(false, |d| Instant::now() >= d) {
        counters.expired_admission.fetch_add(1, Ordering::Relaxed);
        responder.send(
            Status::DeadlineExceeded,
            0,
            Vec::new(),
            "deadline expired at admission".into(),
        );
        return;
    }
    let priority = req.priority;
    match t.queue.submit(NetRequest {
        image: req.image,
        priority,
        deadline,
        submitted: arrival,
        responder,
    }) {
        Ok(()) => {
            counters.accepted.fetch_add(1, Ordering::Relaxed);
        }
        Err((rejected, status)) => {
            match status {
                Status::Shed => {
                    counters.shed[priority.as_u8() as usize].fetch_add(1, Ordering::Relaxed)
                }
                Status::Overflow => counters.overflow.fetch_add(1, Ordering::Relaxed),
                Status::Draining => counters.draining_rejected.fetch_add(1, Ordering::Relaxed),
                // Failed queue: counted by stopped_replies via the send
                _ => 0,
            };
            let mut responder = rejected.responder;
            let msg = match status {
                Status::Shed => {
                    format!("shed: {} priority over admission limit", priority.describe())
                }
                Status::Overflow => "admission queue full".to_string(),
                Status::Draining => "server draining".to_string(),
                _ => "server stopped".to_string(),
            };
            responder.send(status, 0, Vec::new(), msg);
        }
    }
}

/// One connection's reader loop: interruptible frame reads, frame-level
/// validation (typed `BadRequest` + close on malformed bytes — a peer
/// that breaks framing cannot be re-synchronized), admission. The writer
/// half drains `rx` until every responder for this connection resolved.
fn conn_loop(
    stream: TcpStream,
    tenants: Arc<BTreeMap<String, Arc<TenantState>>>,
    counters: Arc<NetCounters>,
    faults: FaultPlan,
    stop: Arc<AtomicBool>,
    poll: Duration,
) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(poll));
    let wstream = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let (tx, rx) = mpsc::channel::<ResponseFrame>();
    let writer = std::thread::spawn(move || {
        let mut w = wstream;
        while let Ok(resp) = rx.recv() {
            if wire::write_frame(&mut w, FrameKind::Response, &resp.encode()).is_err() {
                // peer gone: drain remaining replies so responders never
                // block, then bail
                while rx.recv().is_ok() {}
                break;
            }
        }
        let _ = w.shutdown(Shutdown::Write);
    });
    let mut rstream = stream;
    // in-flight frames get drain_grace poll ticks to finish after stop
    let drain_grace = 500u32;
    loop {
        let mut hdr = [0u8; wire::HEADER_LEN];
        match read_full(&mut rstream, &mut hdr, &stop, false, drain_grace) {
            ReadOutcome::Done => {}
            ReadOutcome::CleanClose | ReadOutcome::Stopped => break,
            ReadOutcome::Torn => {
                counters.disconnects_midframe.fetch_add(1, Ordering::Relaxed);
                break;
            }
        }
        let (kind, body_len) = match wire::decode_header(&hdr) {
            Ok(v) => v,
            Err(e) => {
                // oversized declared lengths land here, BEFORE any body
                // allocation
                counters.malformed.fetch_add(1, Ordering::Relaxed);
                let _ = tx.send(bad_request_reply(0, &e));
                break;
            }
        };
        let mut body = vec![0u8; body_len + 4];
        match read_full(&mut rstream, &mut body, &stop, true, drain_grace) {
            ReadOutcome::Done => {}
            _ => {
                counters.disconnects_midframe.fetch_add(1, Ordering::Relaxed);
                break;
            }
        }
        let crc = body.split_off(body_len);
        if let Err(e) = wire::verify_crc(&body, &crc) {
            counters.malformed.fetch_add(1, Ordering::Relaxed);
            let _ = tx.send(bad_request_reply(0, &e));
            break;
        }
        if kind != FrameKind::Request {
            counters.malformed.fetch_add(1, Ordering::Relaxed);
            let _ = tx.send(bad_request_reply(0, &WireError::Malformed("expected a request frame".into())));
            break;
        }
        let req = match RequestFrame::decode(&body) {
            Ok(r) => r,
            Err(e) => {
                counters.malformed.fetch_add(1, Ordering::Relaxed);
                let _ = tx.send(bad_request_reply(0, &e));
                break;
            }
        };
        admit(req, &tenants, &counters, &faults, &tx);
    }
    // closing the read half tells well-behaved peers we are done reading
    let _ = rstream.shutdown(Shutdown::Read);
    drop(tx);
    let _ = writer.join();
}

// ---------------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------------

/// Final report from [`NetHandle::shutdown`]: merged per-lane serving
/// [`Stats`] (latency reservoir, batches, fills), the exact failure
/// accounting, and any lane errors (injected kills land here — they are
/// an expected outcome of the fault matrix, not a join failure).
#[derive(Debug)]
pub struct NetReport {
    pub stats: Stats,
    pub counts: NetCounts,
    pub lane_errors: Vec<String>,
    pub drain_timed_out: bool,
}

/// Handle to a spawned networked server.
pub struct NetHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    tenants: Arc<BTreeMap<String, Arc<TenantState>>>,
    counters: Arc<NetCounters>,
    cfg: NetConfig,
    accept: Option<JoinHandle<()>>,
    lanes: Vec<(String, JoinHandle<Result<Stats>>)>,
    lanes_done: Arc<AtomicUsize>,
    live_conns: Arc<AtomicUsize>,
}

/// Spawn the networked serving tier: bind `addr` (use port 0 for an
/// ephemeral loopback port), one queue + `spec.lanes` lane threads per
/// registry tenant, an acceptor, and per-connection reader/writer
/// threads. `faults` is consulted at the scripted injection points
/// (pass [`FaultPlan::none`] outside tests).
pub fn spawn(
    addr: impl ToSocketAddrs,
    registry: NetRegistry,
    cfg: NetConfig,
    faults: FaultPlan,
) -> Result<NetHandle> {
    if registry.is_empty() {
        bail!("networked server needs at least one registered tenant");
    }
    // warm the shared kernel pool before any lane spawns (same policy as
    // the in-process server: first-request latency never pays for it)
    crate::kernels::gemm::warm_tiled();
    let listener = TcpListener::bind(addr)?;
    listener.set_nonblocking(true)?;
    let addr = listener.local_addr()?;
    let mut tenants = BTreeMap::new();
    for (name, backend, spec) in registry.entries {
        let t = TenantState {
            name: name.clone(),
            batch: backend.batch(),
            image_elems: backend.image_elems(),
            classes: backend.classes(),
            quota: spec.quota,
            outstanding: Arc::new(AtomicUsize::new(0)),
            queue: NetQueue::new(cfg.serve.queue_depth),
            template: Mutex::new(TenantModel { epoch: 1, backend }),
            epoch: AtomicU64::new(1),
        };
        let lanes = spec.lanes;
        tenants.insert(name, (Arc::new(t), lanes));
    }
    let counters = Arc::new(NetCounters::default());
    let stop = Arc::new(AtomicBool::new(false));
    let lanes_done = Arc::new(AtomicUsize::new(0));
    let live_conns = Arc::new(AtomicUsize::new(0));

    let mut lane_joins = Vec::new();
    for (name, (t, lanes)) in &tenants {
        for lane in 0..*lanes {
            let t = Arc::clone(t);
            let counters = Arc::clone(&counters);
            let faults = faults.clone();
            let done = Arc::clone(&lanes_done);
            let max_wait = cfg.serve.max_wait;
            let join = std::thread::spawn(move || {
                let r = net_lane(Arc::clone(&t), counters.clone(), faults, lane, max_wait);
                if r.is_err() {
                    // fail-stop: answer everything queued with typed
                    // errors instead of stranding the waiting clients
                    let dropped = t.queue.fail();
                    counters.drain_dropped.fetch_add(dropped as u64, Ordering::Relaxed);
                }
                done.fetch_add(1, Ordering::Release);
                r
            });
            lane_joins.push((format!("{name}[{lane}]"), join));
        }
    }

    let tenant_map: Arc<BTreeMap<String, Arc<TenantState>>> =
        Arc::new(tenants.into_iter().map(|(k, (t, _))| (k, t)).collect());
    let accept = {
        let tenants = Arc::clone(&tenant_map);
        let counters = Arc::clone(&counters);
        let stop = Arc::clone(&stop);
        let live = Arc::clone(&live_conns);
        let faults = faults.clone();
        let poll = cfg.poll;
        std::thread::spawn(move || loop {
            if stop.load(Ordering::Relaxed) {
                break;
            }
            match listener.accept() {
                Ok((stream, _peer)) => {
                    counters.connections.fetch_add(1, Ordering::Relaxed);
                    live.fetch_add(1, Ordering::AcqRel);
                    let tenants = Arc::clone(&tenants);
                    let counters = Arc::clone(&counters);
                    let stop = Arc::clone(&stop);
                    let live = Arc::clone(&live);
                    let faults = faults.clone();
                    std::thread::spawn(move || {
                        conn_loop(stream, tenants, counters, faults, stop, poll);
                        live.fetch_sub(1, Ordering::AcqRel);
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(poll);
                }
                Err(_) => break,
            }
        })
    };

    Ok(NetHandle {
        addr,
        stop,
        tenants: tenant_map,
        counters,
        cfg,
        accept: Some(accept),
        lanes: lane_joins,
        lanes_done,
        live_conns,
    })
}

impl NetHandle {
    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Exact failure-accounting snapshot (live; tests poll it).
    pub fn counts(&self) -> NetCounts {
        self.counters.snapshot()
    }

    /// Hot-swap a tenant's multiplication strategy (e.g. a new LUT)
    /// behind its epoch: the template mutates under its lock, the epoch
    /// bumps, and each lane re-clones the template before its next
    /// batch. Returns the new epoch. In-flight batches finish on the
    /// epoch they started with — no request ever sees a partial table.
    pub fn swap_mul(&self, tenant: &str, mul: MulSpec) -> Result<u64, InferError> {
        let t = self
            .tenants
            .get(tenant)
            .ok_or_else(|| InferError::UnknownTenant(tenant.to_string()))?;
        let mut tm = t.template.lock().unwrap();
        tm.backend.set_mul(mul);
        tm.epoch += 1;
        t.epoch.store(tm.epoch, Ordering::Release);
        self.counters.lut_swaps.fetch_add(1, Ordering::Relaxed);
        Ok(tm.epoch)
    }

    /// Graceful shutdown: stop accepting, close the queues for drain,
    /// give admitted work [`NetConfig::drain_deadline`] to finish, then
    /// fail-stop whatever remains (typed errors to its clients, counted
    /// in `drain_dropped`). Returns the merged stats + exact accounting.
    pub fn shutdown(mut self) -> Result<NetReport> {
        self.stop.store(true, Ordering::Release);
        if let Some(a) = self.accept.take() {
            a.join().map_err(|_| anyhow!("accept thread panicked"))?;
        }
        for t in self.tenants.values() {
            t.queue.drain_close();
        }
        let lane_count = self.lanes.len();
        let deadline = Instant::now() + self.cfg.drain_deadline;
        while self.lanes_done.load(Ordering::Acquire) < lane_count && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(1));
        }
        let drain_timed_out = self.lanes_done.load(Ordering::Acquire) < lane_count;
        if drain_timed_out {
            for t in self.tenants.values() {
                let dropped = t.queue.fail();
                self.counters.drain_dropped.fetch_add(dropped as u64, Ordering::Relaxed);
            }
        }
        let mut stats = Stats::default();
        let mut lane_errors = Vec::new();
        for (name, join) in self.lanes.drain(..) {
            match join.join() {
                Ok(Ok(s)) => stats.merge(&s),
                Ok(Err(e)) => lane_errors.push(format!("{name}: {e:#}")),
                Err(_) => lane_errors.push(format!("{name}: lane panicked")),
            }
        }
        // connection threads exit on their next poll tick; bounded wait
        let conn_deadline = Instant::now() + Duration::from_secs(2);
        while self.live_conns.load(Ordering::Acquire) > 0 && Instant::now() < conn_deadline {
            std::thread::sleep(Duration::from_millis(1));
        }
        let counts = self.counters.snapshot();
        // the aggregate reject_rate covers everything turned away at
        // admission, same meaning as the in-process server
        stats.rejected += counts.shed_total() + counts.overflow + counts.draining_rejected;
        Ok(NetReport { stats, counts, lane_errors, drain_timed_out })
    }
}

// ---------------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------------

/// Bounded-retry policy with exponential backoff. Jitter-free by
/// construction ([`RetryPolicy::backoff`] is a pure function of the
/// attempt index), so `sleep = false` gives a fully deterministic test
/// mode — same attempt sequence, no wall-clock dependence.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// total send attempts (>= 1); 1 = never retry
    pub max_attempts: usize,
    pub base_backoff: Duration,
    pub max_backoff: Duration,
    /// false = deterministic test mode: retry immediately, never sleep
    pub sleep: bool,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 1,
            base_backoff: Duration::from_millis(5),
            max_backoff: Duration::from_millis(200),
            sleep: true,
        }
    }
}

impl RetryPolicy {
    /// Backoff before retry number `attempt` (0-based): `base * 2^attempt`
    /// capped at `max_backoff`. Pure — no jitter, no clock reads.
    pub fn backoff(&self, attempt: usize) -> Duration {
        let factor = 1u32 << attempt.min(16) as u32;
        self.base_backoff.saturating_mul(factor).min(self.max_backoff)
    }
}

/// A successful networked reply.
#[derive(Clone, Debug)]
pub struct NetReply {
    pub logits: Vec<f32>,
    /// model epoch that computed the logits (hot-swaps bump it)
    pub epoch: u64,
    /// round-trip latency as observed by the client (includes retries)
    pub latency: Duration,
}

/// Synchronous client over one persistent connection. Retries **only**
/// idempotent rejections (shed/overflow — the server provably did not
/// enqueue the request); an io failure after a request may have reached
/// the wire is [`InferError::Ambiguous`] and is never retried, because
/// the server may have executed it.
pub struct NetClient {
    stream: TcpStream,
    tenant: String,
    next_id: u64,
    retry: RetryPolicy,
}

impl NetClient {
    pub fn connect(
        addr: impl ToSocketAddrs,
        tenant: &str,
        retry: RetryPolicy,
    ) -> Result<NetClient, InferError> {
        assert!(retry.max_attempts >= 1, "max_attempts must be >= 1");
        let stream = TcpStream::connect(addr)
            .map_err(|e| InferError::Transport(format!("connect: {e}")))?;
        let _ = stream.set_nodelay(true);
        Ok(NetClient { stream, tenant: tenant.to_string(), next_id: 1, retry })
    }

    /// One blocking inference call. `deadline` is the per-request budget
    /// carried to the server (relative — no clock sync needed).
    pub fn infer(
        &mut self,
        image: &[f32],
        priority: Priority,
        deadline: Option<Duration>,
    ) -> Result<NetReply, InferError> {
        let start = Instant::now();
        let deadline_ms = deadline
            .map(|d| d.as_millis().clamp(1, u32::MAX as u128) as u32)
            .unwrap_or(0);
        let mut attempt = 0usize;
        loop {
            let id = self.next_id;
            self.next_id += 1;
            let req = RequestFrame {
                id,
                priority,
                deadline_ms,
                tenant: self.tenant.clone(),
                image: image.to_vec(),
            };
            if let Err(e) = wire::write_frame(&mut self.stream, FrameKind::Request, &req.encode())
            {
                // bytes may be on the wire — ambiguous, never retried
                return Err(InferError::Ambiguous(format!("send: {e}")));
            }
            let resp = match wire::read_frame(&mut self.stream) {
                Ok((FrameKind::Response, body)) => ResponseFrame::decode(&body)
                    .map_err(|e| InferError::Transport(format!("bad response: {e}")))?,
                Ok((kind, _)) => {
                    return Err(InferError::Transport(format!("unexpected {kind:?} frame")))
                }
                // the request is in flight and the reply is gone —
                // ambiguous, never retried
                Err(e) => return Err(InferError::Ambiguous(format!("awaiting reply: {e}"))),
            };
            if resp.id != id {
                return Err(InferError::Transport(format!(
                    "response id {} for request {id}",
                    resp.id
                )));
            }
            match resp.status {
                Status::Ok => {
                    return Ok(NetReply {
                        logits: resp.logits,
                        epoch: resp.epoch,
                        latency: start.elapsed(),
                    })
                }
                s if s.idempotent_rejection() && attempt + 1 < self.retry.max_attempts => {
                    if self.retry.sleep {
                        std::thread::sleep(self.retry.backoff(attempt));
                    }
                    attempt += 1;
                }
                s => return Err(status_error(s, priority, &resp.message)),
            }
        }
    }
}

fn status_error(status: Status, priority: Priority, message: &str) -> InferError {
    match status {
        Status::Ok => InferError::Transport("Ok is not an error".into()),
        Status::Shed => InferError::Shed { priority },
        Status::Overflow => InferError::Overloaded,
        Status::DeadlineExceeded => InferError::DeadlineExceeded,
        Status::UnknownTenant => InferError::UnknownTenant(message.to_string()),
        Status::QuotaExceeded => InferError::QuotaExceeded,
        Status::Draining => InferError::Draining,
        Status::Stopped => InferError::Stopped,
        Status::BadRequest => InferError::BadRequest(message.to_string()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admission_limits_are_monotone_in_priority() {
        for depth in [1, 2, 3, 4, 7, 64, 1000] {
            let low = admission_limit(depth, Priority::Low);
            let normal = admission_limit(depth, Priority::Normal);
            let high = admission_limit(depth, Priority::High);
            assert!(low <= normal && normal <= high, "depth {depth}");
            assert_eq!(high, depth, "High only overflows at the hard depth");
            assert!(low >= 1, "every class can make progress on an empty queue");
        }
        assert_eq!(admission_limit(64, Priority::Low), 32);
        assert_eq!(admission_limit(64, Priority::Normal), 48);
        assert_eq!(admission_limit(4, Priority::Low), 2);
        assert_eq!(admission_limit(4, Priority::Normal), 3);
    }

    fn dummy_request(prio: Priority, counters: &Arc<NetCounters>) -> (NetRequest, mpsc::Receiver<ResponseFrame>) {
        let (tx, rx) = mpsc::channel();
        let req = NetRequest {
            image: vec![prio.as_u8() as f32],
            priority: prio,
            deadline: None,
            submitted: Instant::now(),
            responder: Responder::new(prio.as_u8() as u64, tx, Arc::clone(counters)),
        };
        (req, rx)
    }

    #[test]
    fn queue_sheds_by_class_and_pops_high_first() {
        let counters = Arc::new(NetCounters::default());
        let q = NetQueue::new(4); // limits: low 2, normal 3, high 4
        let mut rxs = Vec::new();
        // fill to 2 with low → third low sheds
        for _ in 0..2 {
            let (r, rx) = dummy_request(Priority::Low, &counters);
            q.submit(r).map_err(|_| ()).unwrap();
            rxs.push(rx);
        }
        let (r, _rx) = dummy_request(Priority::Low, &counters);
        match q.submit(r) {
            Err((_, Status::Shed)) => {}
            _ => panic!("expected low shed at occupancy 2"),
        }
        // normal still admitted at occupancy 2, shed at 3
        let (r, rx) = dummy_request(Priority::Normal, &counters);
        q.submit(r).map_err(|_| ()).unwrap();
        rxs.push(rx);
        let (r, _rx2) = dummy_request(Priority::Normal, &counters);
        assert!(matches!(q.submit(r), Err((_, Status::Shed))));
        // high admitted at 3, overflow at 4
        let (r, rx) = dummy_request(Priority::High, &counters);
        q.submit(r).map_err(|_| ()).unwrap();
        rxs.push(rx);
        let (r, _rx3) = dummy_request(Priority::High, &counters);
        assert!(matches!(q.submit(r), Err((_, Status::Overflow))));
        // pop order: the high request first, then normal, then the lows
        let batch = q.pop_batch(4, Duration::ZERO).unwrap();
        let prios: Vec<Priority> = batch.iter().map(|r| r.priority).collect();
        assert_eq!(prios, vec![Priority::High, Priority::Normal, Priority::Low, Priority::Low]);
    }

    #[test]
    fn queue_drain_and_fail_semantics() {
        let counters = Arc::new(NetCounters::default());
        let q = NetQueue::new(8);
        let (r, rx_queued) = dummy_request(Priority::Normal, &counters);
        q.submit(r).map_err(|_| ()).unwrap();
        q.drain_close();
        // draining: no admission, queued work still poppable
        let (r, _rx) = dummy_request(Priority::Normal, &counters);
        assert!(matches!(q.submit(r), Err((_, Status::Draining))));
        let batch = q.pop_batch(4, Duration::ZERO).unwrap();
        assert_eq!(batch.len(), 1);
        drop(batch);
        assert!(q.pop_batch(4, Duration::ZERO).is_none(), "drained queue closes");
        // the popped-and-dropped request got its typed Stopped reply
        let resp = rx_queued.try_recv().unwrap();
        assert_eq!(resp.status, Status::Stopped);
        // fail(): queued requests answered Stopped via responder drop
        let counters2 = Arc::new(NetCounters::default());
        let q = NetQueue::new(8);
        let (r, rx) = dummy_request(Priority::Low, &counters2);
        q.submit(r).map_err(|_| ()).unwrap();
        assert_eq!(q.fail(), 1);
        assert_eq!(rx.try_recv().unwrap().status, Status::Stopped);
        assert_eq!(counters2.stopped_replies.load(Ordering::Relaxed), 1);
        assert!(q.pop_batch(4, Duration::ZERO).is_none());
        assert!(matches!(q.submit(dummy_request(Priority::High, &counters2).0), Err((_, Status::Stopped))));
    }

    #[test]
    fn responder_drop_sends_typed_stopped_exactly_once() {
        let counters = Arc::new(NetCounters::default());
        let (tx, rx) = mpsc::channel();
        let mut r = Responder::new(42, tx, Arc::clone(&counters));
        r.send(Status::Ok, 1, vec![1.0], String::new());
        drop(r);
        assert_eq!(rx.try_recv().unwrap().status, Status::Ok);
        assert!(rx.try_recv().is_err(), "answered responder stays silent on drop");
        let (tx, rx) = mpsc::channel();
        let r = Responder::new(43, tx, Arc::clone(&counters));
        drop(r);
        let resp = rx.try_recv().unwrap();
        assert_eq!((resp.id, resp.status), (43, Status::Stopped));
        assert_eq!(counters.stopped_replies.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn backoff_doubles_capped_and_jitter_free() {
        let p = RetryPolicy {
            max_attempts: 8,
            base_backoff: Duration::from_millis(5),
            max_backoff: Duration::from_millis(35),
            sleep: false,
        };
        assert_eq!(p.backoff(0), Duration::from_millis(5));
        assert_eq!(p.backoff(1), Duration::from_millis(10));
        assert_eq!(p.backoff(2), Duration::from_millis(20));
        assert_eq!(p.backoff(3), Duration::from_millis(35), "capped");
        assert_eq!(p.backoff(60), Duration::from_millis(35), "shift clamp, no overflow");
        // jitter-free: same attempt → same duration, always
        for a in 0..10 {
            assert_eq!(p.backoff(a), p.backoff(a));
        }
    }

    #[test]
    fn registry_rejects_bad_tenants() {
        let mut reg = NetRegistry::new();
        let b = CpuBackend::for_model("lenet300", MulSpec::Native, 2, 1).unwrap();
        reg.add("t0", b.clone(), TenantSpec::default()).unwrap();
        assert!(reg.add("t0", b.clone(), TenantSpec::default()).is_err(), "duplicate");
        assert!(reg.add("", b.clone(), TenantSpec::default()).is_err(), "empty name");
        assert!(
            reg.add("x", b.clone(), TenantSpec { lanes: 0, quota: 0 }).is_err(),
            "zero lanes"
        );
        let long = "x".repeat(wire::MAX_TENANT_LEN + 1);
        assert!(reg.add(&long, b, TenantSpec::default()).is_err(), "over-long name");
    }
}
