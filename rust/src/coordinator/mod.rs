//! Layer-3 coordinator: the pieces that turn compiled artifacts + LUTs +
//! datasets into the paper's experiments.
//!
//! * [`trainer`] — the training/evaluation driver over the PJRT engine
//!   (one fused train-step call per batch; Python never runs here).
//! * [`pruning`] — magnitude pruning with a polynomial-decay schedule
//!   (Fig 11).
//! * [`server`] — a threaded batching inference server (router/batcher) to
//!   exercise the inference path the way a deployment would.
//! * [`experiments`] — the harness that regenerates every paper
//!   table/figure (also callable from `cargo bench`).
//! * [`report`] — markdown/CSV emitters for EXPERIMENTS.md.
pub mod experiments;
pub mod pruning;
pub mod report;
pub mod server;
pub mod trainer;
