//! Transpose-And-Reverse kernel (paper §VI-D).
//!
//! For the preceding-layer gradient, `QuantizedWeights^l` must be both
//! transposed (swap input/output channel dims) and spatially reversed.
//! Doing this inside the GEMM via index manipulation would destroy memory
//! coalescing, so the paper — and we — spend a separate pass that
//! rearranges the data once; the GEMM then streams it contiguously.

/// `w[kh, kw, c, oc]` -> `wrt[kh, kw, oc, c]` with both spatial dims
/// reversed: `wrt[ky, kx, oc, c] = w[kh-1-ky, kw-1-kx, c, oc]`.
pub fn transpose_reverse(
    w: &[f32],
    k_h: usize,
    k_w: usize,
    in_c: usize,
    out_c: usize,
) -> Vec<f32> {
    let mut out = vec![0.0f32; w.len()];
    transpose_reverse_into(w, k_h, k_w, in_c, out_c, &mut out);
    out
}

/// [`transpose_reverse`] writing into a caller-provided buffer (the conv
/// layer's implicit path routes this through a recycled scratch so the
/// steady-state backward pass stays allocation-free).
///
/// Per spatial cell the channel swap is exactly a dense `in_c x out_c`
/// transpose, so it reuses the cache-blocked [`super::transpose_into`]
/// instead of paying a full column stride on every write.
pub fn transpose_reverse_into(
    w: &[f32],
    k_h: usize,
    k_w: usize,
    in_c: usize,
    out_c: usize,
    out: &mut [f32],
) {
    assert_eq!(w.len(), k_h * k_w * in_c * out_c);
    assert_eq!(out.len(), w.len());
    let cell = in_c * out_c;
    for ky in 0..k_h {
        for kx in 0..k_w {
            let src_spatial = ((k_h - 1 - ky) * k_w + (k_w - 1 - kx)) * cell;
            let dst_spatial = (ky * k_w + kx) * cell;
            // out[dst + oc*in_c + c] = w[src + c*out_c + oc]: a row-major
            // in_c x out_c -> out_c x in_c transpose of the cell
            super::transpose_into(
                &w[src_spatial..src_spatial + cell],
                in_c,
                out_c,
                &mut out[dst_spatial..dst_spatial + cell],
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn involution_on_symmetric_dims() {
        // applying twice with swapped channel dims restores the original
        let mut rng = Pcg32::seeded(51);
        let (kh, kw, c, oc) = (3, 3, 4, 5);
        let w: Vec<f32> = (0..kh * kw * c * oc).map(|_| rng.range(-1.0, 1.0)).collect();
        let once = transpose_reverse(&w, kh, kw, c, oc);
        let twice = transpose_reverse(&once, kh, kw, oc, c);
        assert_eq!(w, twice);
    }

    #[test]
    fn explicit_small_case() {
        // 2x1 kernel, 1 in channel, 2 out channels
        // w[ky][kx][c][oc]: w[0,0,0,:] = [1,2]; w[1,0,0,:] = [3,4]
        let w = vec![1.0, 2.0, 3.0, 4.0];
        let wrt = transpose_reverse(&w, 2, 1, 1, 2);
        // wrt[0,0,oc,c] = w[1,0,c,oc] -> [3,4]; wrt[1,0,oc,c] = w[0,0] -> [1,2]
        assert_eq!(wrt, vec![3.0, 4.0, 1.0, 2.0]);
    }

    /// Channel dims straddling the 8x8 transpose blocking must still
    /// satisfy the per-element definition.
    #[test]
    fn blocked_cells_match_scalar_definition() {
        let mut rng = Pcg32::seeded(52);
        let (kh, kw, c, oc) = (2, 3, 11, 19);
        let w: Vec<f32> = (0..kh * kw * c * oc).map(|_| rng.range(-1.0, 1.0)).collect();
        let got = transpose_reverse(&w, kh, kw, c, oc);
        for ky in 0..kh {
            for kx in 0..kw {
                for ci in 0..c {
                    for o in 0..oc {
                        let want = w[(((kh - 1 - ky) * kw + (kw - 1 - kx)) * c + ci) * oc + o];
                        let have = got[((ky * kw + kx) * oc + o) * c + ci];
                        assert_eq!(have.to_bits(), want.to_bits(), "({ky},{kx},{ci},{o})");
                    }
                }
            }
        }
    }

    #[test]
    fn identity_for_1x1_single_channels() {
        let w = vec![7.0];
        assert_eq!(transpose_reverse(&w, 1, 1, 1, 1), vec![7.0]);
    }
}
