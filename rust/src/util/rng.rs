//! Deterministic PRNG used everywhere seeds matter: dataset synthesis,
//! weight initialization, shuffling, property tests.
//!
//! The paper trains every multiplier variant from the *same random seed* so
//! curves are comparable (§VIII-A); a fully deterministic, dependency-free
//! generator is therefore part of the reproduction contract.

/// PCG32 (O'Neill 2014), the `pcg_setseq_64_xsh_rr_32` member.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Seed with a stream id; distinct `(seed, stream)` pairs give
    /// independent sequences.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Seed with stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` without modulo bias (Lemire rejection).
    pub fn below(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u32();
            let m = (x as u64).wrapping_mul(bound as u64);
            let l = m as u32;
            if l >= bound || l >= (bound.wrapping_neg() % bound) {
                return (m >> 32) as u32;
            }
        }
    }

    /// Uniform in `[0, bound)` without modulo bias — the 64-bit analog of
    /// [`below`](Pcg32::below) (Lemire multiply-shift with rejection). A
    /// plain `next_u64() % bound` overrepresents the low residues
    /// whenever `bound` does not divide `2^64`.
    pub fn below_u64(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let l = m as u64;
            if l >= bound || l >= bound.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn uniform(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.uniform();
            if u1 <= f32::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * (u1 as f64).ln()).sqrt();
            let th = 2.0 * std::f64::consts::PI * u2 as f64;
            return (r * th.cos()) as f32;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// A random *finite, normal-range* f32 with full sign/exponent/mantissa
    /// coverage — used by multiplier property tests.
    pub fn finite_f32(&mut self) -> f32 {
        loop {
            let bits = self.next_u32();
            let v = f32::from_bits(bits);
            if v.is_finite() && (v == 0.0 || v.abs() >= f32::MIN_POSITIVE) {
                return v;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg32::new(42, 54);
        let mut b = Pcg32::new(42, 54);
        for _ in 0..1000 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg32::new(42, 0);
        let mut b = Pcg32::new(42, 1);
        let same = (0..100).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 3);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Pcg32::seeded(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn below_u64_is_in_range_and_covers() {
        let mut r = Pcg32::seeded(8);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below_u64(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
        // bounds beyond u32: stays in range (the branch `% u64::MAX` bias
        // would skew)
        let big = (u32::MAX as u64) * 3 + 7;
        for _ in 0..1000 {
            assert!(r.below_u64(big) < big);
        }
        // agrees with the 32-bit path on distribution: mean of [0, 1000)
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.below_u64(1000) as f64).sum::<f64>() / n as f64;
        assert!((mean - 499.5).abs() < 15.0, "mean {mean}");
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Pcg32::seeded(1);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.uniform() as f64).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(2);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal() as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(3);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn finite_f32_is_finite() {
        let mut r = Pcg32::seeded(4);
        for _ in 0..10_000 {
            assert!(r.finite_f32().is_finite());
        }
    }
}
