//! Checkpoint resume determinism: training interrupted by a
//! save-to-disk / reload round trip must land on **bit-identical** final
//! weights versus an uninterrupted run with the same `Pcg32` seed and the
//! same batch stream. This is the invariant the Table IV cross-format
//! machinery and the pruning flow (load, prune, retrain) rest on: a
//! checkpoint is a *complete* capture of training state for the pure-SGD
//! CPU nets, and the `.ckpt` container round-trips every f32 exactly.

use approxtrain::amsim::AmSim;
use approxtrain::kernels::MulKernel;
use approxtrain::lut::MantissaLut;
use approxtrain::mult::registry;
use approxtrain::nn::checkpoint::Checkpoint;
use approxtrain::nn::cpu_lenet::Lenet300;
use approxtrain::tensor::Tensor;
use approxtrain::util::rng::Pcg32;

const N_IN: usize = 36;
const CLASSES: usize = 10;
const BATCH: usize = 16;
const TOTAL_STEPS: usize = 8;
const SPLIT_AT: usize = 4;

/// Deterministic batch stream shared by both runs.
fn batches(seed: u64) -> Vec<(Tensor, Vec<u32>)> {
    let mut rng = Pcg32::seeded(seed);
    (0..TOTAL_STEPS)
        .map(|_| {
            let x = Tensor::from_vec(
                &[BATCH, N_IN],
                (0..BATCH * N_IN).map(|_| rng.range(-1.0, 1.0)).collect(),
            );
            let labels: Vec<u32> = (0..BATCH).map(|_| rng.below(CLASSES as u32)).collect();
            (x, labels)
        })
        .collect()
}

fn params<'a>(net: &'a Lenet300) -> Vec<(&'static str, &'a Tensor)> {
    vec![
        ("w1", &net.w1),
        ("b1", &net.b1),
        ("w2", &net.w2),
        ("b2", &net.b2),
        ("w3", &net.w3),
        ("b3", &net.b3),
    ]
}

fn to_checkpoint(net: &Lenet300) -> Checkpoint {
    let mut ckpt = Checkpoint::default();
    for (name, t) in params(net) {
        ckpt.insert(name, &t.shape, t.data.clone());
    }
    ckpt
}

fn restore(net: &mut Lenet300, ckpt: &Checkpoint) {
    for (name, t) in [
        ("w1", &mut net.w1),
        ("b1", &mut net.b1),
        ("w2", &mut net.w2),
        ("b2", &mut net.b2),
        ("w3", &mut net.w3),
        ("b3", &mut net.b3),
    ] {
        let (shape, data) = ckpt.get(name).unwrap_or_else(|| panic!("missing {name}"));
        assert_eq!(*shape, t.shape, "{name} shape");
        t.data.clone_from(data);
    }
}

#[test]
fn resumed_training_is_bit_identical_to_uninterrupted() {
    let model = registry::by_name("afm16").unwrap();
    let lut = MantissaLut::generate(model.as_ref());
    let mul = MulKernel::Lut(AmSim::new(&lut));
    let data = batches(4242);
    let seed = 77;
    let lr = 0.05;

    // run A: uninterrupted
    let mut net_a = Lenet300::init(N_IN, CLASSES, seed);
    for (x, labels) in &data {
        net_a.train_step(&mul, x, labels, lr);
    }

    // run B: train to SPLIT_AT, checkpoint through disk, resume into a
    // *differently-initialized* net (proves the restore overwrites
    // everything), finish on the same batch stream
    let mut net_b = Lenet300::init(N_IN, CLASSES, seed);
    for (x, labels) in &data[..SPLIT_AT] {
        net_b.train_step(&mul, x, labels, lr);
    }
    let path = std::env::temp_dir().join("approxtrain_resume_test/mid.ckpt");
    to_checkpoint(&net_b).save(&path).unwrap();
    drop(net_b);

    let mut resumed = Lenet300::init(N_IN, CLASSES, seed + 999);
    let ckpt = Checkpoint::load(&path).unwrap();
    restore(&mut resumed, &ckpt);
    for (x, labels) in &data[SPLIT_AT..] {
        resumed.train_step(&mul, x, labels, lr);
    }

    for ((name, ta), (_, tb)) in params(&net_a).into_iter().zip(params(&resumed)) {
        assert_eq!(ta.shape, tb.shape, "{name} shape");
        for i in 0..ta.data.len() {
            assert_eq!(
                ta.data[i].to_bits(),
                tb.data[i].to_bits(),
                "{name}[{i}]: {} vs {} — resume diverged",
                ta.data[i],
                tb.data[i]
            );
        }
    }
}

/// The checkpoint container must round-trip f32 payloads bit-exactly,
/// including negative zero and values with no short decimal form.
#[test]
fn checkpoint_f32_roundtrip_is_exact() {
    let mut ckpt = Checkpoint::default();
    let vals = vec![
        -0.0f32,
        f32::MIN_POSITIVE,
        1.0 + f32::EPSILON,
        -3.141_592_7,
        f32::MAX,
        1e-40, // subnormal
    ];
    ckpt.insert("t", &[vals.len()], vals.clone());
    let back = Checkpoint::from_bytes(&ckpt.to_bytes()).unwrap();
    let (_, data) = back.get("t").unwrap();
    for (i, (a, b)) in vals.iter().zip(data).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "idx {i}");
    }
}
