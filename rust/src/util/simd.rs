//! Runtime SIMD capability detection and the `APPROXTRAIN_SIMD` override
//! knob.
//!
//! The micro-kernel hot paths ([`crate::amsim::AmSim::mul_microtile`] and
//! the native arm of `kernels::MulKernel`) carry hand-written AVX2
//! specializations next to their portable scalar bodies. Which body runs
//! is a *data* question answered here, once per process:
//!
//! 1. probe the CPU with `is_x86_feature_detected!` ([`SimdLevel::detected`],
//!    cached);
//! 2. let the `APPROXTRAIN_SIMD` environment variable lower (never raise)
//!    the probe ([`active`], cached) — `scalar` forces the portable
//!    fallback everywhere, `avx2`/`avx2fma` pin a vector tier, anything
//!    else (or `auto`) keeps the detection result. A request the machine
//!    cannot execute is **clamped down** to what it can, so forcing
//!    `avx2` on a non-AVX2 host (or any non-x86-64 host) degrades to
//!    `scalar` instead of faulting — which is what makes the
//!    forced-level differential suites runnable on any machine.
//!
//! ## Why a level can never change results
//!
//! Every vector arm keeps the crate-wide accumulation contract by
//! running its SIMD lanes **across independent accumulator chains**
//! (the `MR x NR` micro-tile accumulators, or the `acc[j]` chains of a
//! rank-1 update), never *along* one chain: each output element still
//! receives its products one at a time, in ascending contraction order,
//! through the exact scalar add sequence. Vectorizing along a chain
//! (summing partial lanes and folding them) would reassociate FP
//! addition and silently change bits — that is the failure mode
//! `tests/simd_lanes.rs` exists to catch, and why the single-chain
//! [`crate::kernels::MulBackend::dot_panel_acc`] only vectorizes its
//! *product* computation (gather + decomposition, which are exact
//! integer ops) while the adds stay serial.
//!
//! The same reasoning bans FMA *contraction*: `acc = fma(a, b, acc)`
//! single-rounds `a*b + acc` where the contract's `acc += a * b`
//! rounds twice, so the [`SimdLevel::Avx2Fma`] native arm uses FMA only
//! in product position with a `-0.0` addend (`fma(a, b, -0.0)`), which
//! is bit-identical to `a * b` for every input — including the sign of
//! an exactly-zero product, which a `+0.0` addend would flip.

use std::sync::OnceLock;

/// Environment variable that lowers the SIMD tier (see module docs).
pub const ENV_KNOB: &str = "APPROXTRAIN_SIMD";

/// The SIMD tier a kernel dispatch runs at. Ordered: a higher level is a
/// strict superset of the features of every lower one, so clamping a
/// request to the machine's capability is `min`.
///
/// * [`SimdLevel::Scalar`] — the portable body. Compiled everywhere, the
///   everywhere-fallback *and the oracle*: every vector arm is gated
///   bit-identical to it.
/// * [`SimdLevel::Avx2`] — x86-64 AVX2: `vpgatherdd` LUT-row gathers and
///   vectorized sign/exponent/mantissa decomposition, 8 FP32 lanes
///   spread across independent accumulator chains.
/// * [`SimdLevel::Avx2Fma`] — AVX2 + FMA: the native arm additionally
///   computes products with `vfmadd` in the contract-legal
///   `fma(a, b, -0.0)` form (module docs). The LUT arm is the AVX2 one
///   (gathers have no FMA to use).
///
/// `Direct` multiplier kernels are scalar at every level: the per-multiply
/// virtual call into the functional model cannot be vectorized, which is
/// the paper's ATxC-vs-ATxG cost argument in miniature.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum SimdLevel {
    Scalar,
    Avx2,
    Avx2Fma,
}

impl SimdLevel {
    /// Stable lowercase name (the `APPROXTRAIN_SIMD` vocabulary and the
    /// bench-record row suffix).
    pub fn name(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Avx2 => "avx2",
            SimdLevel::Avx2Fma => "avx2fma",
        }
    }

    /// Parse one concrete level name (see [`resolve`] for the full knob
    /// grammar, which also understands `auto`).
    pub fn parse(s: &str) -> Option<SimdLevel> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(SimdLevel::Scalar),
            "avx2" => Some(SimdLevel::Avx2),
            "avx2fma" | "avx2+fma" | "fma" => Some(SimdLevel::Avx2Fma),
            _ => None,
        }
    }

    /// The highest level this machine can execute — one cached
    /// `is_x86_feature_detected!` probe. Always [`SimdLevel::Scalar`] on
    /// non-x86-64 targets.
    pub fn detected() -> SimdLevel {
        static DETECTED: OnceLock<SimdLevel> = OnceLock::new();
        *DETECTED.get_or_init(probe)
    }

    /// Clamp `self` to what this machine can execute (`min` with
    /// [`SimdLevel::detected`]). Forced-level constructors route through
    /// this so an impossible request degrades instead of faulting.
    pub fn clamp_to_machine(self) -> SimdLevel {
        self.min(SimdLevel::detected())
    }
}

impl std::fmt::Display for SimdLevel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(target_arch = "x86_64")]
fn probe() -> SimdLevel {
    if is_x86_feature_detected!("avx2") {
        if is_x86_feature_detected!("fma") {
            SimdLevel::Avx2Fma
        } else {
            SimdLevel::Avx2
        }
    } else {
        SimdLevel::Scalar
    }
}

#[cfg(not(target_arch = "x86_64"))]
fn probe() -> SimdLevel {
    SimdLevel::Scalar
}

/// Pure resolution of the override knob: what level is active given the
/// raw `APPROXTRAIN_SIMD` value (`None` = unset) and the detected
/// capability. Unset / empty / `auto` keep the detection result; a
/// recognized level is clamped down to `detected`; an unrecognized value
/// is ignored with a warning (detection wins) rather than silently
/// changing behaviour.
pub fn resolve(env: Option<&str>, detected: SimdLevel) -> SimdLevel {
    match env {
        None => detected,
        Some(raw) => {
            let s = raw.trim().to_ascii_lowercase();
            if s.is_empty() || s == "auto" || s == "detect" {
                detected
            } else if let Some(req) = SimdLevel::parse(&s) {
                req.min(detected)
            } else {
                eprintln!(
                    "warning: unrecognized {ENV_KNOB}={raw:?} \
                     (expected scalar|avx2|avx2fma|auto); using detected '{detected}'"
                );
                detected
            }
        }
    }
}

/// The process-wide active level: [`resolve`] of the `APPROXTRAIN_SIMD`
/// environment variable against [`SimdLevel::detected`], computed once
/// and cached (one atomic load per call afterwards — cheap enough for
/// per-panel dispatch). Kernel objects that want a *different* level
/// take it explicitly (`AmSim::with_simd`, `MulKernel::NativeAt`)
/// instead of mutating this.
pub fn active() -> SimdLevel {
    static ACTIVE: OnceLock<SimdLevel> = OnceLock::new();
    *ACTIVE.get_or_init(|| resolve(std::env::var(ENV_KNOB).ok().as_deref(), SimdLevel::detected()))
}

/// Every level this machine can execute, ascending — always starts with
/// [`SimdLevel::Scalar`], ends with [`SimdLevel::detected`]. The
/// iteration domain of the forced-level differential suites and the
/// bench's per-level rows.
pub fn available_levels() -> Vec<SimdLevel> {
    [SimdLevel::Scalar, SimdLevel::Avx2, SimdLevel::Avx2Fma]
        .into_iter()
        .filter(|&l| l <= SimdLevel::detected())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_are_ordered_and_clamping_is_min() {
        assert!(SimdLevel::Scalar < SimdLevel::Avx2);
        assert!(SimdLevel::Avx2 < SimdLevel::Avx2Fma);
        assert_eq!(SimdLevel::Avx2Fma.min(SimdLevel::Scalar), SimdLevel::Scalar);
        assert!(SimdLevel::Avx2Fma.clamp_to_machine() <= SimdLevel::detected());
    }

    #[test]
    fn parse_and_name_round_trip() {
        for l in [SimdLevel::Scalar, SimdLevel::Avx2, SimdLevel::Avx2Fma] {
            assert_eq!(SimdLevel::parse(l.name()), Some(l));
        }
        assert_eq!(SimdLevel::parse(" AVX2 "), Some(SimdLevel::Avx2));
        assert_eq!(SimdLevel::parse("neon"), None);
    }

    #[test]
    fn resolve_grammar() {
        let det = SimdLevel::detected();
        assert_eq!(resolve(None, det), det);
        assert_eq!(resolve(Some(""), det), det);
        assert_eq!(resolve(Some("auto"), det), det);
        assert_eq!(resolve(Some("scalar"), det), SimdLevel::Scalar);
        // a request is clamped down to the machine, never raised
        assert_eq!(resolve(Some("avx2fma"), SimdLevel::Scalar), SimdLevel::Scalar);
        assert_eq!(resolve(Some("avx2fma"), SimdLevel::Avx2), SimdLevel::Avx2);
        assert_eq!(resolve(Some("scalar"), SimdLevel::Avx2Fma), SimdLevel::Scalar);
        // junk is ignored in favour of detection
        assert_eq!(resolve(Some("sse9"), det), det);
    }

    #[test]
    fn active_is_stable_and_machine_executable() {
        let a = active();
        assert_eq!(active(), a, "active level must be cached, not re-resolved");
        assert!(a <= SimdLevel::detected());
    }

    #[test]
    fn available_levels_ascend_from_scalar_to_detected() {
        let levels = available_levels();
        assert_eq!(levels.first(), Some(&SimdLevel::Scalar));
        assert_eq!(levels.last(), Some(&SimdLevel::detected()));
        assert!(levels.windows(2).all(|w| w[0] < w[1]));
    }
}
