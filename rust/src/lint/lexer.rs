//! Comment/string-aware source scrubber — the foundation every
//! `approxlint` rule stands on.
//!
//! [`scrub`] splits a Rust source text into two same-shape channels:
//!
//! * **code** — the original text with every comment, string literal,
//!   byte/raw string and char literal replaced by spaces (newlines
//!   preserved), so token scans can never false-positive on doc prose
//!   or log messages;
//! * **comments** — the inverse: only comment text survives (including
//!   its `//`/`/*` markers), everything else is spaces. This is what
//!   the `SAFETY:` rule reads.
//!
//! Both channels keep `\n` exactly where the source has it, so a line
//! number means the same thing in the raw text and in either channel.
//! The scrubber understands nested block comments, raw strings
//! (`r#"…"#`, any hash depth), byte and byte-raw strings, escaped
//! string contents, and the char-literal-vs-lifetime ambiguity
//! (`'a'` scrubs, `'a>` and `'window:` survive as code).

/// The two scrubbed channels of one source file. Same line structure as
/// the input; see module docs.
pub struct Scrubbed {
    pub code: String,
    pub comments: String,
}

fn is_ident(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Scrub `src` into its code and comment channels.
pub fn scrub(src: &str) -> Scrubbed {
    let cs: Vec<char> = src.chars().collect();
    let n = cs.len();
    let mut code = String::with_capacity(src.len());
    let mut com = String::with_capacity(src.len());
    // channel writers: every char lands in exactly one channel; the
    // other gets a space (newlines land in both so lines stay aligned)
    let keep = |code: &mut String, com: &mut String, c: char| {
        code.push(c);
        com.push(if c == '\n' { '\n' } else { ' ' });
    };
    let comment = |code: &mut String, com: &mut String, c: char| {
        code.push(if c == '\n' { '\n' } else { ' ' });
        com.push(c);
    };
    let blank = |code: &mut String, com: &mut String, c: char| {
        let w = if c == '\n' { '\n' } else { ' ' };
        code.push(w);
        com.push(w);
    };

    let mut i = 0;
    while i < n {
        let c = cs[i];
        // line comment (incl. /// and //!)
        if c == '/' && i + 1 < n && cs[i + 1] == '/' {
            while i < n && cs[i] != '\n' {
                comment(&mut code, &mut com, cs[i]);
                i += 1;
            }
            continue;
        }
        // block comment, nesting-aware
        if c == '/' && i + 1 < n && cs[i + 1] == '*' {
            comment(&mut code, &mut com, cs[i]);
            comment(&mut code, &mut com, cs[i + 1]);
            i += 2;
            let mut depth = 1usize;
            while i < n && depth > 0 {
                if cs[i] == '/' && i + 1 < n && cs[i + 1] == '*' {
                    depth += 1;
                    comment(&mut code, &mut com, cs[i]);
                    comment(&mut code, &mut com, cs[i + 1]);
                    i += 2;
                } else if cs[i] == '*' && i + 1 < n && cs[i + 1] == '/' {
                    depth -= 1;
                    comment(&mut code, &mut com, cs[i]);
                    comment(&mut code, &mut com, cs[i + 1]);
                    i += 2;
                } else {
                    comment(&mut code, &mut com, cs[i]);
                    i += 1;
                }
            }
            continue;
        }
        // raw / byte string prefixes: r"…", r#"…"#, b"…", br#"…"#
        // (only at an identifier boundary, so `carry`/`br` idents pass)
        if (c == 'r' || c == 'b') && (i == 0 || !is_ident(cs[i - 1])) {
            let mut j = i;
            if cs[j] == 'b' && j + 1 < n && (cs[j + 1] == 'r' || cs[j + 1] == '"' || cs[j + 1] == '\'')
            {
                j += 1;
            }
            if j < n && cs[j] == 'r' {
                let mut k = j + 1;
                let mut hashes = 0usize;
                while k < n && cs[k] == '#' {
                    hashes += 1;
                    k += 1;
                }
                if k < n && cs[k] == '"' {
                    // raw (byte) string from i ..= closing quote + hashes
                    while i <= k {
                        blank(&mut code, &mut com, cs[i]);
                        i += 1;
                    }
                    loop {
                        if i >= n {
                            break;
                        }
                        if cs[i] == '"' {
                            let mut h = 0usize;
                            while h < hashes && i + 1 + h < n && cs[i + 1 + h] == '#' {
                                h += 1;
                            }
                            if h == hashes {
                                for _ in 0..=hashes {
                                    blank(&mut code, &mut com, cs[i]);
                                    i += 1;
                                }
                                break;
                            }
                        }
                        blank(&mut code, &mut com, cs[i]);
                        i += 1;
                    }
                    continue;
                }
            }
            if j < n && (cs[j] == '"' || cs[j] == '\'') && cs[i] == 'b' {
                // byte string b"…" or byte char b'…': blank the prefix,
                // then fall through to the quote handling below
                blank(&mut code, &mut com, cs[i]);
                i = j;
                // handled by the '"' / '\'' branches on the next pass
                // (cs[i] is now the quote)
            }
        }
        let c = cs[i];
        // plain string literal with escapes
        if c == '"' {
            blank(&mut code, &mut com, c);
            i += 1;
            while i < n {
                if cs[i] == '\\' && i + 1 < n {
                    blank(&mut code, &mut com, cs[i]);
                    blank(&mut code, &mut com, cs[i + 1]);
                    i += 2;
                    continue;
                }
                let done = cs[i] == '"';
                blank(&mut code, &mut com, cs[i]);
                i += 1;
                if done {
                    break;
                }
            }
            continue;
        }
        // char literal vs lifetime/label
        if c == '\'' {
            let escaped = i + 1 < n && cs[i + 1] == '\\';
            let simple = i + 2 < n && cs[i + 2] == '\'' && cs[i + 1] != '\'';
            if escaped {
                blank(&mut code, &mut com, cs[i]);
                i += 1;
                while i < n {
                    if cs[i] == '\\' && i + 1 < n {
                        blank(&mut code, &mut com, cs[i]);
                        blank(&mut code, &mut com, cs[i + 1]);
                        i += 2;
                        continue;
                    }
                    let done = cs[i] == '\'';
                    blank(&mut code, &mut com, cs[i]);
                    i += 1;
                    if done {
                        break;
                    }
                }
                continue;
            }
            if simple {
                blank(&mut code, &mut com, cs[i]);
                blank(&mut code, &mut com, cs[i + 1]);
                blank(&mut code, &mut com, cs[i + 2]);
                i += 3;
                continue;
            }
            // lifetime or loop label: stays code
            keep(&mut code, &mut com, c);
            i += 1;
            continue;
        }
        keep(&mut code, &mut com, c);
        i += 1;
    }
    Scrubbed { code, comments: com }
}

/// Byte offsets (into a scrubbed channel) where each line starts.
pub fn line_offsets(s: &str) -> Vec<usize> {
    let mut offs = vec![0usize];
    for (i, b) in s.bytes().enumerate() {
        if b == b'\n' {
            offs.push(i + 1);
        }
    }
    offs
}

/// 1-based line number of byte `pos` given [`line_offsets`].
pub fn line_of(offsets: &[usize], pos: usize) -> usize {
    match offsets.binary_search(&pos) {
        Ok(i) => i + 1,
        Err(i) => i, // insertion point i means line i (1-based)
    }
}

/// All positions where `word` occurs in `hay` with no identifier
/// character on either side.
pub fn find_word(hay: &str, word: &str) -> Vec<usize> {
    let hb = hay.as_bytes();
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(rel) = hay[from..].find(word) {
        let pos = from + rel;
        let left_ok = pos == 0 || !is_ident_byte(hb[pos - 1]);
        let end = pos + word.len();
        let right_ok = end >= hb.len() || !is_ident_byte(hb[end]);
        if left_ok && right_ok {
            out.push(pos);
        }
        from = pos + 1;
    }
    out
}

/// All positions where `pat` occurs in `hay` (plain substring search).
pub fn find_sub(hay: &str, pat: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(rel) = hay[from..].find(pat) {
        out.push(from + rel);
        from = from + rel + 1;
    }
    out
}

pub fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Byte position of the first non-whitespace code byte after the last
/// statement boundary (`;`, `{` or `}`) before `pos` — the start of the
/// statement/item containing `pos`. Comments are already spaces in the
/// code channel, so a comment between the boundary and the statement is
/// skipped like whitespace.
pub fn statement_start(code: &str, pos: usize) -> usize {
    let b = code.as_bytes();
    let mut i = pos;
    let mut boundary = 0usize;
    while i > 0 {
        i -= 1;
        if b[i] == b';' || b[i] == b'{' || b[i] == b'}' {
            boundary = i + 1;
            break;
        }
    }
    let mut j = boundary;
    while j < pos && (b[j] as char).is_whitespace() {
        j += 1;
    }
    j
}

/// Position of the `{` opening the innermost block that contains `pos`,
/// or `None` at item/file level.
pub fn enclosing_open(code: &str, pos: usize) -> Option<usize> {
    let b = code.as_bytes();
    let mut depth = 0i32;
    let mut i = pos;
    while i > 0 {
        i -= 1;
        match b[i] {
            b'}' => depth += 1,
            b'{' => {
                if depth == 0 {
                    return Some(i);
                }
                depth -= 1;
            }
            _ => {}
        }
    }
    None
}

/// Position of the `}` matching the `{` at `open`, or `None` if the
/// file is unbalanced.
pub fn matching_close(code: &str, open: usize) -> Option<usize> {
    let b = code.as_bytes();
    let mut depth = 0i32;
    let mut i = open;
    while i < b.len() {
        match b[i] {
            b'{' => depth += 1,
            b'}' => {
                depth -= 1;
                if depth == 0 {
                    return Some(i);
                }
            }
            _ => {}
        }
        i += 1;
    }
    None
}

/// First word of the statement that introduces the block opening at
/// `open` — `"while"`, `"loop"`, `"if"`, `"fn"`, … Loop labels
/// (`'drain: loop {`) are skipped.
pub fn block_keyword(code: &str, open: usize) -> String {
    let start = statement_start(code, open);
    let b = code.as_bytes();
    let mut i = start;
    // skip a loop label: 'name :
    if i < b.len() && b[i] == b'\'' {
        i += 1;
        while i < b.len() && is_ident_byte(b[i]) {
            i += 1;
        }
        while i < b.len() && ((b[i] as char).is_whitespace() || b[i] == b':') {
            i += 1;
        }
    }
    let word_start = i;
    while i < b.len() && is_ident_byte(b[i]) {
        i += 1;
    }
    code[word_start..i].to_string()
}

/// Identifier immediately before byte `pos` (skipping whitespace and one
/// index expression `[…]`), e.g. the receiver field of `.lock(` /
/// `.wait(` call chains. Empty string when the receiver is not a plain
/// identifier.
pub fn ident_before(code: &str, pos: usize) -> String {
    let b = code.as_bytes();
    let mut i = pos;
    while i > 0 && (b[i - 1] as char).is_whitespace() {
        i -= 1;
    }
    if i > 0 && b[i - 1] == b']' {
        // skip one index expression: …[li]
        let mut depth = 0i32;
        while i > 0 {
            i -= 1;
            match b[i] {
                b']' => depth += 1,
                b'[' => {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                _ => {}
            }
        }
    }
    let end = i;
    while i > 0 && is_ident_byte(b[i - 1]) {
        i -= 1;
    }
    code[i..end].to_string()
}

/// Normalization used by the allowlist formats: the scrubbed code line
/// with every whitespace character removed (comments and string
/// contents are already spaces, so they vanish too). Whitespace-free
/// keys make the allowlist grammar unambiguous (` | ` can never occur
/// inside a key) and are trivial to regenerate by hand.
pub fn normalize_line(code_line: &str) -> String {
    code_line.chars().filter(|c| !c.is_whitespace()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scrub_separates_channels() {
        let src = "let x = 1; // SAFETY: note\nlet s = \"unsafe Ordering::SeqCst\";\n";
        let sc = scrub(src);
        assert!(!sc.code.contains("SAFETY"));
        assert!(!sc.code.contains("Ordering"));
        assert!(sc.code.contains("let x = 1;"));
        assert!(sc.comments.contains("// SAFETY: note"));
        assert_eq!(sc.code.matches('\n').count(), 2);
        assert_eq!(sc.comments.matches('\n').count(), 2);
    }

    #[test]
    fn scrub_handles_nested_and_raw() {
        let src = "/* a /* b */ c */ fn f() {} r#\"raw \" unsafe\"# 'x' 'a: b\"esc\\\"q\" ";
        let sc = scrub(src);
        assert!(sc.code.contains("fn f() {}"));
        assert!(!sc.code.contains("unsafe"));
        assert!(!sc.code.contains("raw"));
        assert!(!sc.code.contains("esc"));
        // the label survives as code, the char literal does not
        assert!(sc.code.contains("'a:"));
        assert!(!sc.code.contains("'x'"));
    }

    #[test]
    fn word_and_statement_helpers() {
        let code = "fn f() { let y = 2; let x = unsafe_marker; }";
        assert_eq!(find_word(code, "unsafe"), Vec::<usize>::new());
        let p = find_word(code, "unsafe_marker")[0];
        let s = statement_start(code, p);
        assert!(code[s..].starts_with("let x"));
        let open = enclosing_open(code, p).unwrap();
        assert_eq!(code.as_bytes()[open], b'{');
        assert_eq!(matching_close(code, open), Some(code.len() - 1));
    }

    #[test]
    fn block_keyword_reads_header() {
        let code = "fn f() { while x < 3 { y(); } 'lbl: loop { z(); } }";
        let w_open = code.find("{ y").unwrap();
        assert_eq!(block_keyword(code, w_open), "while");
        let l_open = code.find("{ z").unwrap();
        assert_eq!(block_keyword(code, l_open), "loop");
    }

    #[test]
    fn ident_before_skips_index_and_ws() {
        let code = "slots_ref[li].lock()";
        let p = code.find(".lock").unwrap();
        assert_eq!(ident_before(code, p), "slots_ref");
        let code2 = "self.inner.kill_after\n    .lock()";
        let p2 = code2.find(".lock").unwrap();
        assert_eq!(ident_before(code2, p2), "kill_after");
    }

    #[test]
    fn normalize_strips_all_whitespace() {
        assert_eq!(normalize_line("  a . b ( 1 ,  2 ) ;  "), "a.b(1,2);");
    }
}
