//! Lane-differential bit-exactness net for the SIMD kernel arms.
//!
//! The AVX2 paths (`amsim::simd` for the LUT gather, `kernels::simd` for
//! the native baseline) claim bit-identity to the portable scalar bodies
//! at every [`SimdLevel`]. This suite is the acceptance gate for that
//! claim: every (multiplier ∈ {native, direct:m, lut:m for all registry
//! models with m ≤ 8}) × (forced `SimdLevel`) × (shape residue hitting
//! every lane remainder `0..LANES` and every `MR`/`NR` edge) is compared
//! against the per-element scalar replay, **bitwise**. Operand panels
//! carry planted IEEE edge values (signed zeros, subnormal-flush,
//! overflow-saturating magnitudes, infinities) at head / mid / tail lane
//! positions, so masked lanes in the vector arms are exercised at every
//! position within a vector.
//!
//! Forcing is per kernel object — [`AmSim::with_simd`] for the LUT arm,
//! [`MulKernel::NativeAt`] for the native arm (`Direct` is scalar at
//! every level by design) — so all levels run in one process. The
//! process-wide `APPROXTRAIN_SIMD` knob is covered separately: ci.sh
//! runs this whole suite twice (default detection and forced `scalar`),
//! and `active_level_matches_pure_resolution_of_env` pins the knob's
//! resolution against the pure [`simd::resolve`] function under
//! whichever environment the suite was launched with.

use approxtrain::amsim::AmSim;
use approxtrain::kernels::gemm::{gemm_scalar_reference, gemm_tiled_with, TileConfig};
use approxtrain::kernels::{MulBackend, MulKernel, SimdLevel};
use approxtrain::lut::MantissaLut;
use approxtrain::mult::{registry, ApproxMul};
use approxtrain::util::rng::Pcg32;
use approxtrain::util::simd;

/// AVX2 FP32 lane width — the vector arms chunk columns by this, so the
/// shape sweeps below cover every remainder `0..LANES` (and then some).
const LANES: usize = 8;

/// Widest mantissa whose LUT this suite tabulates (matches the
/// golden-vector suite's ceiling; every registry model with m ≤ 8 rides).
const MAX_LUT_M: u32 = 8;

struct Tabulated {
    model: Box<dyn ApproxMul>,
    lut: MantissaLut,
}

fn tabulated() -> Vec<Tabulated> {
    registry::names()
        .iter()
        .filter_map(|name| registry::by_name(name))
        .filter(|m| m.mantissa_bits() <= MAX_LUT_M)
        .map(|model| {
            let lut = MantissaLut::generate(model.as_ref());
            Tabulated { model, lut }
        })
        .collect()
}

/// Run `f` over the full forced-level × multiplier matrix: for each
/// machine-executable level, the native kernel pinned at that level, and
/// per tabulatable model both its LUT kernel pinned at that level and
/// its direct kernel (scalar at every level by design — included so the
/// matrix witnesses that levels cannot change it either).
fn for_each_forced_kernel(f: &mut dyn FnMut(&MulKernel, &str)) {
    let tabs = tabulated();
    assert!(!tabs.is_empty(), "registry lost all m<=8 models");
    for level in simd::available_levels() {
        f(&MulKernel::NativeAt(level), &format!("native@{level}"));
        for t in &tabs {
            f(
                &MulKernel::Lut(AmSim::with_simd(&t.lut, level)),
                &format!("lut:{}@{level}", t.model.name()),
            );
            f(
                &MulKernel::Direct(t.model.as_ref()),
                &format!("direct:{}@{level}", t.model.name()),
            );
        }
    }
}

/// Operand panel with planted IEEE edge values at head / mid / tail lane
/// positions: signed zeros (flush-add paths), subnormal (flushes), a
/// magnitude pair that saturates to infinity on multiply, and infinities
/// themselves (huge-exponent lanes for the LUT arm, IEEE inf for native).
fn edge_vec(rng: &mut Pcg32, n: usize) -> Vec<f32> {
    let mut v: Vec<f32> = (0..n).map(|_| rng.range(-3.0, 3.0)).collect();
    let plant = [
        0.0f32,
        -0.0,
        f32::MIN_POSITIVE / 2.0, // subnormal
        1e30,                    // overflow partner
        -1e30,
        f32::INFINITY,
        f32::NEG_INFINITY,
        1e-25, // underflow partner
    ];
    // lane positions 0, mid, tail of the first vector, plus the very end
    // of the panel (the scalar-tail region when n % LANES != 0)
    let slots = [0usize, LANES / 2, LANES - 1, n / 2, n.saturating_sub(1)];
    for (i, &s) in slots.iter().enumerate() {
        if s < n {
            v[s] = plant[i % plant.len()];
        }
    }
    v
}

fn assert_bits(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for i in 0..got.len() {
        assert_eq!(
            got[i].to_bits(),
            want[i].to_bits(),
            "{what} idx {i}: {} vs {}",
            got[i],
            want[i]
        );
    }
}

/// Per-element scalar replay of the `mul_microtile` contract.
fn microtile_ref(
    mul: &MulKernel,
    acc: &mut [f32],
    a: &[f32],
    b: &[f32],
    mr: usize,
    nr: usize,
    k_len: usize,
) {
    for kk in 0..k_len {
        for r in 0..mr {
            for c in 0..nr {
                acc[r * nr + c] += mul.mul(a[r * k_len + kk], b[kk * nr + c]);
            }
        }
    }
}

/// The core matrix: `mul_microtile` at every `nr ∈ 1..=16` (every lane
/// remainder twice, both the sub-lane widths and the `NR_MAX` edge),
/// `mr ∈ {1, 3, 4, 16}` (unit, odd, default, `MR_MAX`), `k` hitting the
/// empty/unit/odd/deep cases — for every forced-level kernel, against
/// the per-element scalar replay, bitwise.
#[test]
fn microtile_forced_level_matrix_matches_scalar_replay() {
    for_each_forced_kernel(&mut |mul, label| {
        for nr in 1..=16usize {
            for mr in [1usize, 3, 4, 16] {
                for k_len in [0usize, 1, 5, 13] {
                    let mut rng = Pcg32::seeded(7000 + (nr * 997 + mr * 89 + k_len) as u64);
                    let a = edge_vec(&mut rng, mr * k_len);
                    let b = edge_vec(&mut rng, k_len * nr);
                    let init = edge_vec(&mut rng, mr * nr);
                    let mut got = init.clone();
                    mul.mul_microtile(&mut got, &a, &b, mr, nr, k_len);
                    let mut want = init;
                    microtile_ref(mul, &mut want, &a, &b, mr, nr, k_len);
                    assert_bits(&got, &want, &format!("[{label}] {mr}x{nr} k={k_len}"));
                }
            }
        }
    });
}

/// `mul_panel` / `fma_row` / `dot_panel_acc` at every length residue
/// `0..=2*LANES+1` plus a deep panel — covering the all-tail, one-chunk,
/// chunk-plus-every-tail and many-chunk cases of the vector arms.
#[test]
fn panel_ops_forced_level_matrix_matches_scalar_replay() {
    let mut lens: Vec<usize> = (0..=2 * LANES + 1).collect();
    lens.push(64);
    lens.push(65);
    for_each_forced_kernel(&mut |mul, label| {
        for &n in &lens {
            let mut rng = Pcg32::seeded(7600 + n as u64);
            let a = edge_vec(&mut rng, n);
            let b = edge_vec(&mut rng, n);
            // mul_panel
            let mut out = vec![0.0f32; n];
            mul.mul_panel(&a, &b, &mut out);
            let want: Vec<f32> = (0..n).map(|i| mul.mul(a[i], b[i])).collect();
            assert_bits(&out, &want, &format!("[{label}] mul_panel n={n}"));
            // dot: single chain, ascending adds
            let got = mul.dot_panel_acc(0.25, &a, &b);
            let mut acc = 0.25f32;
            for i in 0..n {
                acc += mul.mul(a[i], b[i]);
            }
            assert_bits(&[got], &[acc], &format!("[{label}] dot n={n}"));
            // fma_row, with zero / nonzero broadcast operands (the zero
            // operand drives the all-lanes-flushed vector path)
            for x in [1.375f32, -0.0, 0.0, 2.5e30] {
                let mut row_acc = edge_vec(&mut rng, n);
                let mut row_ref = row_acc.clone();
                mul.fma_row(&mut row_acc, x, &b);
                for i in 0..n {
                    row_ref[i] += mul.mul(x, b[i]);
                }
                assert_bits(&row_acc, &row_ref, &format!("[{label}] fma_row x={x} n={n}"));
            }
        }
    });
}

/// Whole-GEMM differential at forced levels: the tiled micro-kernel path
/// over `(m % MR, n % NR)` residues × threads {1, 8} against the scalar
/// dispatch oracle — the same sweep `tests/microtile.rs` runs at the
/// active level, here pinned per level so both vector arms and the
/// scalar fallback are exercised in one process.
#[test]
fn gemm_tiled_forced_levels_match_scalar_oracle() {
    let model = registry::by_name("afm16").unwrap();
    let lut = MantissaLut::generate(model.as_ref());
    let cfg = TileConfig { mc: 8, kc: 16, nc: 16, mr: 4, nr: 8 };
    let k = 37;
    for level in simd::available_levels() {
        let kernels = [
            MulKernel::NativeAt(level),
            MulKernel::Lut(AmSim::with_simd(&lut, level)),
        ];
        for mul in &kernels {
            for m in 12..16 {
                for n in 16..24 {
                    let mut rng = Pcg32::seeded(8100 + (m * 100 + n) as u64);
                    let a = edge_vec(&mut rng, m * k);
                    let b = edge_vec(&mut rng, k * n);
                    let mut want = vec![0.0f32; m * n];
                    gemm_scalar_reference(mul, &a, &b, &mut want, m, k, n);
                    for threads in [1usize, 8] {
                        let mut got = vec![0.0f32; m * n];
                        gemm_tiled_with(mul, cfg, &a, &b, &mut got, m, k, n, threads);
                        assert_bits(
                            &got,
                            &want,
                            &format!(
                                "[{}] ({m},{k},{n}) residue ({},{}) t={threads}",
                                mul.describe(),
                                m % 4,
                                n % 8
                            ),
                        );
                    }
                }
            }
        }
    }
}

/// Odd-offset smoke: the vector arms use unaligned loads/stores
/// throughout, so panels starting 1..3 floats into an allocation (4, 8,
/// 12 bytes — never 32-byte aligned) must work and stay bit-identical.
/// This is what lets packed panels land anywhere in the recycled buffers
/// without alignment luck.
#[test]
fn unaligned_odd_offset_panels_match_scalar_replay() {
    let n = 2 * LANES + 3;
    for_each_forced_kernel(&mut |mul, label| {
        let mut rng = Pcg32::seeded(9300);
        let a_buf = edge_vec(&mut rng, n + 4);
        let b_buf = edge_vec(&mut rng, n + 4);
        for off in 1..=3usize {
            let a = &a_buf[off..off + n];
            let b = &b_buf[off..off + n];
            let mut out_buf = vec![0.0f32; n + 4];
            mul.mul_panel(a, b, &mut out_buf[off..off + n]);
            let want: Vec<f32> = (0..n).map(|i| mul.mul(a[i], b[i])).collect();
            assert_bits(&out_buf[off..off + n], &want, &format!("[{label}] off={off} panel"));
            // micro-tile over the same offset slices (nr=9: one vector
            // chunk plus a scalar-tail column — operands and acc all at
            // odd offsets)
            let (mr, nr, k_len) = (2usize, 9usize, 2usize);
            let mut acc_buf = edge_vec(&mut rng, mr * nr + off);
            let mut acc_ref: Vec<f32> = acc_buf[off..].to_vec();
            mul.mul_microtile(
                &mut acc_buf[off..],
                &a[..mr * k_len],
                &b[..k_len * nr],
                mr,
                nr,
                k_len,
            );
            microtile_ref(mul, &mut acc_ref, &a[..mr * k_len], &b[..k_len * nr], mr, nr, k_len);
            assert_bits(&acc_buf[off..], &acc_ref, &format!("[{label}] off={off} microtile"));
        }
    });
}

/// The cached process-wide level must equal the pure resolution of the
/// actual environment against the actual detection — under ci.sh's
/// second pass (`APPROXTRAIN_SIMD=scalar`) this pins the knob end to
/// end: active() is then `Scalar` and every unforced kernel in the rest
/// of the suite ran the portable fallback.
#[test]
fn active_level_matches_pure_resolution_of_env() {
    let env = std::env::var(simd::ENV_KNOB).ok();
    let expect = simd::resolve(env.as_deref(), SimdLevel::detected());
    assert_eq!(simd::active(), expect, "env={env:?}");
    assert!(simd::active() <= SimdLevel::detected());
    if env.as_deref() == Some("scalar") {
        assert_eq!(simd::active(), SimdLevel::Scalar);
    }
}

/// Forcing a tier the machine lacks degrades (clamps) instead of
/// faulting: requesting Avx2Fma everywhere must still run — and still
/// match the scalar replay — even on a host detected below it.
#[test]
fn impossible_level_requests_clamp_and_stay_correct() {
    let model = registry::by_name("afm16").unwrap();
    let lut = MantissaLut::generate(model.as_ref());
    let sim = AmSim::with_simd(&lut, SimdLevel::Avx2Fma);
    assert!(sim.simd_level() <= SimdLevel::detected());
    let kernels = [
        MulKernel::NativeAt(SimdLevel::Avx2Fma),
        MulKernel::Lut(sim),
    ];
    let n = LANES + 3;
    let mut rng = Pcg32::seeded(9500);
    let a = edge_vec(&mut rng, n);
    let b = edge_vec(&mut rng, n);
    for mul in &kernels {
        let mut out = vec![0.0f32; n];
        mul.mul_panel(&a, &b, &mut out);
        let want: Vec<f32> = (0..n).map(|i| mul.mul(a[i], b[i])).collect();
        assert_bits(&out, &want, &format!("[{}] clamped", mul.describe()));
    }
}
