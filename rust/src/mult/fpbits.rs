//! IEEE-754 single-precision bit plumbing shared by every multiplier model,
//! the LUT generator (Algorithm 1) and AMSim (Algorithm 2).
//!
//! Field layout of an FP32 word: `sign(1) | exponent(8, bias 127) |
//! mantissa(23)`. All "m-bit" formats in the paper keep sign=1 and
//! exponent=8 and vary only the mantissa width (§VII *Datatype*), so a
//! narrower format is an FP32 whose mantissa has only the top `m` bits set.

pub const SIGN_MASK: u32 = 0x8000_0000;
pub const EXP_MASK: u32 = 0x7F80_0000;
pub const MANT_MASK: u32 = 0x007F_FFFF;
pub const EXP_BIAS: i32 = 127;
pub const MANT_BITS: u32 = 23;

/// Decomposed FP32.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FpParts {
    /// 0 or 1
    pub sign: u32,
    /// biased exponent, 0..=255
    pub exp: u32,
    /// 23-bit mantissa field
    pub mant: u32,
}

pub fn decompose(v: f32) -> FpParts {
    let bits = v.to_bits();
    FpParts {
        sign: bits >> 31,
        exp: (bits & EXP_MASK) >> MANT_BITS,
        mant: bits & MANT_MASK,
    }
}

pub fn compose(p: FpParts) -> f32 {
    debug_assert!(p.sign <= 1 && p.exp <= 255 && p.mant <= MANT_MASK);
    f32::from_bits((p.sign << 31) | (p.exp << MANT_BITS) | p.mant)
}

/// Round-to-nearest-even quantization of the mantissa to `m` bits,
/// propagating a rounding carry into the exponent. Zeros/inf/NaN pass
/// through; subnormals flush to zero (AMSim has no subnormal support —
/// paper Alg. 2 line 13 flushes them too).
pub fn quantize_mantissa(v: f32, m: u32) -> f32 {
    assert!((1..=MANT_BITS).contains(&m));
    if v == 0.0 || !v.is_finite() {
        return v;
    }
    let p = decompose(v);
    if p.exp == 0 {
        return if p.sign == 1 { -0.0 } else { 0.0 };
    }
    if m == MANT_BITS {
        return v;
    }
    let drop = MANT_BITS - m;
    let half = 1u32 << (drop - 1);
    let low = p.mant & ((1 << drop) - 1);
    let mut kept = p.mant >> drop;
    // round-to-nearest, ties-to-even
    if low > half || (low == half && kept & 1 == 1) {
        kept += 1;
    }
    let mut exp = p.exp;
    if kept >> m != 0 {
        // mantissa overflowed to 2.0 — renormalize
        kept = 0;
        exp += 1;
        if exp >= 255 {
            return if p.sign == 1 { f32::NEG_INFINITY } else { f32::INFINITY };
        }
    }
    compose(FpParts { sign: p.sign, exp, mant: kept << drop })
}

/// True if `v` has no significant bits below the top `m` mantissa bits
/// (i.e. it is exactly representable in the (1,8,m) format).
pub fn representable_in(v: f32, m: u32) -> bool {
    let p = decompose(v);
    v == 0.0 || (p.exp > 0 && p.mant & ((1 << (MANT_BITS - m)) - 1) == 0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::for_all;

    #[test]
    fn decompose_compose_roundtrip() {
        for v in [0.0f32, 1.0, -1.0, 1.5, -3.375, 1e-20, 1e20, f32::MIN_POSITIVE] {
            assert_eq!(compose(decompose(v)).to_bits(), v.to_bits(), "{v}");
        }
    }

    #[test]
    fn known_fields() {
        let p = decompose(1.0);
        assert_eq!((p.sign, p.exp, p.mant), (0, 127, 0));
        let p = decompose(-1.5);
        assert_eq!((p.sign, p.exp, p.mant), (1, 127, 1 << 22));
    }

    #[test]
    fn quantize_bf16_examples() {
        // 1 + 2^-7 is representable with m=7; 1 + 2^-8 rounds to 1.0 (even)
        assert_eq!(quantize_mantissa(1.0 + 2f32.powi(-7), 7), 1.0 + 2f32.powi(-7));
        assert_eq!(quantize_mantissa(1.0 + 2f32.powi(-8), 7), 1.0);
        // tie rounds to even: 1 + 3*2^-8 -> 1 + 2*2^-7? (3/256 -> tie at 1.5/128 -> 2/128)
        assert_eq!(quantize_mantissa(1.0 + 3.0 * 2f32.powi(-8), 7), 1.0 + 2.0 * 2f32.powi(-7));
    }

    #[test]
    fn quantize_carry_into_exponent() {
        // just below 2.0 rounds up to 2.0
        let v = 2.0 - 2f32.powi(-9);
        assert_eq!(quantize_mantissa(v, 7), 2.0);
    }

    #[test]
    fn quantize_flushes_subnormals() {
        assert_eq!(quantize_mantissa(f32::MIN_POSITIVE / 2.0, 7), 0.0);
    }

    #[test]
    fn quantize_idempotent_property() {
        for_all(
            "quantize-idempotent",
            11,
            5000,
            |r| (r.finite_f32(), 1 + r.below(23)),
            |&(v, m)| {
                let q = quantize_mantissa(v, m);
                let qq = quantize_mantissa(q, m);
                if q.to_bits() == qq.to_bits() || (q == 0.0 && qq == 0.0) {
                    Ok(())
                } else {
                    Err(format!("quantize({v}, {m}) = {q} re-quantized to {qq}"))
                }
            },
        );
    }

    #[test]
    fn quantized_is_representable() {
        for_all(
            "quantized-representable",
            12,
            5000,
            |r| (r.finite_f32(), 1 + r.below(23)),
            |&(v, m)| {
                let q = quantize_mantissa(v, m);
                if !q.is_finite() || representable_in(q, m) {
                    Ok(())
                } else {
                    Err(format!("quantize({v}, {m}) = {q} not representable"))
                }
            },
        );
    }
}
