"""Mantissa-product LUT generation — paper §V-A Algorithm 1, Python mirror
of ``rust/src/lut``. Writes the identical binary format (magic, header,
little-endian u32 payload, CRC-32) so Rust↔Python bit-exactness can be
asserted on the files themselves.

Run as a module to regenerate all tabulatable LUTs::

    python -m compile.lutgen --out ../artifacts/luts
"""

from __future__ import annotations

import argparse
import os
import zlib

import numpy as np

from . import mults
from .fp_bits import EXP_BIAS, MANT_BITS, compose, decompose

MAGIC = b"AMLUT\x01\x00\x00"
MAX_LUT_M = 12


def generate(mult: mults.Mult) -> np.ndarray:
    """Algorithm 1, vectorized: probe the black-box ``mul`` over the full
    mantissa grid with fixed non-special exponents and recover carry bits
    from the result exponents."""
    m = mult.m
    assert m <= MAX_LUT_M, f"mantissa width {m} not tabulatable"
    exp_a = exp_b = 127  # N = K = 127 (same choice as the Rust generator)
    k = np.arange(1 << m, dtype=np.uint32)
    kk, jj = np.meshgrid(k, k, indexing="ij")
    a = compose(0, exp_a, (kk << np.uint32(MANT_BITS - m)).ravel())
    b = compose(0, exp_b, (jj << np.uint32(MANT_BITS - m)).ravel())
    c = mult.mul(a, b)
    _, ec, mc = decompose(c)
    un_normalized = exp_a + exp_b - EXP_BIAS
    carry = (ec.astype(np.int64) > un_normalized).astype(np.uint32)
    return ((carry << np.uint32(MANT_BITS)) | mc).astype(np.uint32)


def to_bytes(name: str, m: int, entries: np.ndarray) -> bytes:
    header = MAGIC + np.uint32(m).tobytes() + np.uint32(len(name)).tobytes()
    header += name.encode()
    payload = entries.astype("<u4").tobytes()
    crc = np.uint32(zlib.crc32(payload) & 0xFFFFFFFF).tobytes()
    return header + payload + crc


def write_lut(mult: mults.Mult, path: str) -> None:
    entries = generate(mult)
    with open(path, "wb") as f:
        f.write(to_bytes(mult.name, mult.m, entries))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/luts")
    ap.add_argument("--mults", nargs="*", default=mults.LUT_ABLE)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    for name in args.mults:
        mult = mults.by_name(name)
        path = os.path.join(args.out, f"{name}.lut")
        write_lut(mult, path)
        print(f"wrote {path} (m={mult.m}, {4 << (2 * mult.m)} bytes payload)")


if __name__ == "__main__":
    main()
