//! Analytic hardware cost model for FP multiplier datapaths — the substrate
//! for reproducing Fig 1 (area/power efficiency of FP32/FP16/bfloat16/
//! AFM32/AFM16, normalized to FP32).
//!
//! The paper synthesizes RTL with Cadence RC on a TSMC 45nm library; that
//! toolchain is unavailable here, so we use a classical *unit-gate* model
//! (see DESIGN.md §Substitutions #8): every 2-input NAND/NOR counts 1 gate
//! of area and 1 unit of switching energy, a full adder 7 gates, a half
//! adder 3, XOR 2. Area and dynamic power are both proportional to the
//! gate count in this model (activity factor assumed uniform), which is
//! enough to recover the *relative ordering and rough factors* of Fig 1.
//!
//! Datapath inventory per multiplier (mantissa width m, exponent width e):
//!
//! * exact FP: (m+1)x(m+1) partial-product array (AND gates) + Dadda
//!   reduction (~(m+1)^2 - (m+1) full adders) + final (2m+2)-bit adder +
//!   e-bit exponent adder + rounding incrementer + sign XOR.
//! * log-based (Mitchell): one m-bit adder for the mantissas + exponent
//!   adder + sign XOR — no partial products at all.
//! * AFM (minimally biased): Mitchell core + k x k partial-product array +
//!   two m-bit compensation adders.
//! * REALM: Mitchell core + two 8-entry constant-LUT correction stages.

/// Unit-gate costs.
const FA: f64 = 7.0; // full adder
const AND: f64 = 1.0;
const XOR: f64 = 2.0;

/// Cost estimate for one multiplier design.
#[derive(Clone, Debug)]
pub struct HwCost {
    pub name: String,
    /// unit-gate count (proportional to area)
    pub gates: f64,
    /// switching energy per multiply (proportional to power at fixed clock)
    pub energy: f64,
}

fn ripple_adder(bits: f64) -> f64 {
    bits * FA
}

/// Exact FP multiplier with `m` mantissa and `e` exponent bits.
pub fn exact_fp(name: &str, m: u32, e: u32) -> HwCost {
    let mm = (m + 1) as f64; // significand width incl. hidden bit
    let partial_products = mm * mm * AND;
    let reduction = (mm * mm - mm) * FA; // Dadda/Wallace tree, depth-summed
    let final_add = ripple_adder(2.0 * mm);
    let exponent = ripple_adder(e as f64 + 1.0);
    let rounding = ripple_adder(mm); // incrementer
    let gates = partial_products + reduction + final_add + exponent + rounding + XOR;
    // the mantissa stage dominates switching (paper §V: 91%/93% of
    // area/power); uniform activity makes energy proportional to gates
    HwCost { name: name.into(), gates, energy: gates }
}

/// Mitchell-style log multiplier (mantissa adder only).
pub fn log_mult(name: &str, m: u32, e: u32) -> HwCost {
    let gates = ripple_adder(m as f64) + ripple_adder(e as f64 + 1.0) + XOR;
    HwCost { name: name.into(), gates, energy: gates }
}

/// AFM: Mitchell core + k x k truncated partial-product array + two small
/// compensation adders.
pub fn afm(name: &str, m: u32, e: u32, k: u32) -> HwCost {
    let base = log_mult(name, m, e);
    let kk = k as f64;
    let pp = kk * kk * AND + (kk * kk - kk) * FA;
    // compensation operands are k+2 bits wide (the xy partial product and
    // the shifted (x+y) term only carry into the top bits)
    let comp = 2.0 * ripple_adder(kk + 2.0);
    HwCost { name: name.into(), gates: base.gates + pp + comp, energy: base.energy + pp + comp }
}

/// REALM: Mitchell core + two constant-LUT correction stages (8-entry
/// decoder + m-bit correction adder each).
pub fn realm(name: &str, m: u32, e: u32) -> HwCost {
    let base = log_mult(name, m, e);
    let lut_stage = 2.0 * (8.0 * 3.0 + ripple_adder(m as f64));
    HwCost { name: name.into(), gates: base.gates + lut_stage, energy: base.energy + lut_stage }
}

/// The Fig 1 series: efficiency (1/area, 1/power) of each design
/// normalized to FP32 (higher is better). Rows are
/// `(name, area_efficiency, power_efficiency)` in the figure's order.
pub fn fig1_series() -> Vec<(String, f64, f64)> {
    let designs = vec![
        exact_fp("FP32", 23, 8),
        exact_fp("FP16", 10, 5),
        exact_fp("bfloat16", 7, 8),
        afm("AFM32", 23, 8, 6),
        afm("AFM16", 7, 8, 4),
        log_mult("MIT16", 7, 8),
        realm("REALM16", 7, 8),
    ];
    let base = designs[0].clone();
    designs
        .into_iter()
        .map(|d| (d.name.clone(), base.gates / d.gates, base.energy / d.energy))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Fig 1's qualitative claims: AFM32 ~12x smaller than FP32; AFM16 well
    /// above bfloat16; ordering FP32 < FP16 < bfloat16 < AFM designs.
    #[test]
    fn fig1_ordering_holds() {
        let rows = fig1_series();
        let eff = |name: &str| rows.iter().find(|r| r.0 == name).unwrap().1;
        assert!((eff("FP32") - 1.0).abs() < 1e-12);
        assert!(eff("FP16") > eff("FP32"));
        assert!(eff("bfloat16") > eff("FP16"));
        assert!(eff("AFM32") > eff("bfloat16"), "Fig 1 ordering: AFM32 {} vs bf16 {}",
                eff("AFM32"), eff("bfloat16"));
        assert!(eff("AFM32") > 5.0, "AFM32 area eff {}", eff("AFM32"));
        assert!(eff("AFM16") > eff("bfloat16") * 2.0);
        assert!(eff("MIT16") > eff("AFM16")); // strictly simpler datapath
    }

    #[test]
    fn exact_costs_grow_quadratically_in_mantissa() {
        let c7 = exact_fp("a", 7, 8).gates;
        let c23 = exact_fp("b", 23, 8).gates;
        let ratio = c23 / c7;
        assert!(ratio > 6.0 && ratio < 12.0, "ratio {ratio}");
    }

    #[test]
    fn log_mult_is_cheapest() {
        assert!(log_mult("m", 7, 8).gates < realm("r", 7, 8).gates);
        assert!(realm("r", 7, 8).gates < afm("a", 7, 8, 4).gates);
        assert!(afm("a", 7, 8, 4).gates < exact_fp("e", 7, 8).gates);
    }
}
