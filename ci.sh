#!/usr/bin/env bash
# CI pipeline: format check (advisory), release build, tests, bench smoke.
# Usage: ./ci.sh
set -uo pipefail

cd "$(dirname "$0")"

fail=0
step() { echo; echo "==> $*"; }

step "cargo fmt --check (advisory)"
if command -v rustfmt >/dev/null 2>&1 || cargo fmt --version >/dev/null 2>&1; then
    if ! cargo fmt --all -- --check; then
        # advisory only: formatting drift is reported but does not gate the
        # build/test/bench pipeline (tier-1 is build + test)
        echo "WARNING: formatting drift detected (run 'cargo fmt')"
    fi
else
    echo "rustfmt not installed; skipping format check"
fi

step "clippy (advisory)"
if cargo clippy --version >/dev/null 2>&1; then
    if ! cargo clippy --all-targets -- -D warnings; then
        # advisory only, like fmt: lint drift is reported but tier-1 stays
        # build + test + approxlint
        echo "WARNING: clippy warnings detected"
    fi
else
    echo "clippy not installed; skipping"
fi

step "test registration check (every rust/tests/*.rs declared in Cargo.toml)"
# autotests is off (sources live under rust/), so an unregistered test
# file would silently never run — fail loudly instead
for f in rust/tests/*.rs; do
    if ! grep -Fq "path = \"$f\"" Cargo.toml; then
        echo "ERROR: $f is not registered as a [[test]] target in Cargo.toml"
        fail=1
    fi
done

step "approxlint (static-analysis pass: determinism, unsafe, atomics, accumulation)"
# the in-repo lint (rust/src/lint/, docs/LINTS.md) runs before the main
# build: R1 SAFETY comments, R2 deterministic-module bans, R3 audited
# atomics vs rust/lint/atomics.allow, R4 accumulation-contract shapes vs
# rust/lint/accum.allow, R5 condvar/lock discipline, R6 paired SIMD
# gates, R7 registration/schema cross-checks. Gating, not advisory: a
# finding fails CI.
cargo run -q --release --bin approxlint -- . || fail=1

step "cargo build --release"
cargo build --release || fail=1

step "cargo test -q (unit tests, debug assertions on)"
# unit tests run in debug for the debug_assert coverage; the heavy
# integration sweeps (golden vectors, GEMM property grids) are deferred
# to the release pass below so they only run once, optimized
cargo test -q --lib --bins --examples || fail=1

step "cargo test --release -q (full suite incl. integration, release mode, detected SIMD)"
# the golden-vector and GEMM property sweeps are sized for release-mode
# speed; running them optimized also exercises the code the benches ship.
# This pass runs at the machine's detected SIMD level — the forced-level
# matrices inside the suites additionally pin every lower level per
# kernel object, so one pass covers scalar/avx2/avx2fma arms.
cargo test --release -q || fail=1

step "cargo test --release -q with APPROXTRAIN_SIMD=scalar (portable-fallback pass)"
# second full pass with the process-wide knob forcing the scalar
# fallback: every *unforced* kernel (the default construction the
# trainer, server and benches use) now runs the portable body, and
# simd_lanes' env-resolution test asserts active() == Scalar — together
# the two passes prove the knob reaches every dispatch site end to end
APPROXTRAIN_SIMD=scalar cargo test --release -q || fail=1

step "bit-exactness suites (release): implicit-GEMM conv + micro-kernel edges + SIMD lanes + sparse skipping + serving + data-parallel + networked tier"
# already part of the full release suite above, but pinned here explicitly
# so the implicit-conv acceptance sweep, the MRxNR micro-kernel residue
# sweep, the SIMD lane-differential net (forced-level x multiplier x
# residue matrix, incl. the odd-offset unaligned-buffer smoke), the
# zero-skipping sparse-GEMM net (occupancy-residue x sparsity x
# multiplier x level x threads vs the dense scalar oracle, the native
# dense-fallback NaN proof, the lying-zero-identity teeth and the
# closed-form skip-counter check), the serving-layer gates (multi-lane
# ≡ single-lane replies, partial-batch cycle-padding, bounded-queue
# rejection), and the data-parallel determinism gates (N-worker loss
# curves ≡ 1-worker, sharded-checkpoint resume, aligned grad
# accumulation, fail-stop on replica panic, masked sparse training), and
# the networked-tier gates (loopback replies ≡ in-process serve_on_caller
# bits, every scripted fault -> typed error, deadline/shedding/quota
# accounting, epoch-atomic LUT hot swap, graceful-drain semantics) can
# never silently drop out of the release-mode pass
# (--test lint re-runs the lint teeth + the shipped-tree meta-check in
# the same release pass)
cargo test --release -q --test conv_grads --test batched_vs_scalar --test microtile \
    --test simd_lanes --test sparse_gemm --test server --test data_parallel \
    --test serve_net --test lint || fail=1

step "bench smoke (tiny sizes; does not touch the committed BENCH records)"
# the gemm smoke rows include the micro-kernel tiled path (and its mr1nr1
# per-element-drain ablation row) plus the structured-sparsity sweep
# (0/50/90% rows with occupancy-bitmap zero-skipping for flagged
# multipliers, dense fallback for native), each behind the bench's own
# bit-exactness gate against the scalar oracle; the serve smoke sweeps
# lanes x load with every accepted reply gated against the single-lane
# reference forward; the train smoke sweeps workers x strategy with every
# multi-worker run gated bit-identical (loss curve + final params) to its
# 1-worker twin
cargo bench --bench paper_benches -- gemm --smoke || fail=1
cargo bench --bench paper_benches -- conv --smoke || fail=1
cargo bench --bench paper_benches -- serve --smoke || fail=1
# networked-tier smoke: the same serve sweep plus a loopback TCP pass
# through the wire protocol / deadline / shedding path, with every
# accepted reply bit-gated against the cycle-padded reference forward
cargo bench --bench paper_benches -- serve --net --smoke || fail=1
cargo bench --bench paper_benches -- train --smoke || fail=1

echo
if [ "$fail" -ne 0 ]; then
    echo "CI: FAILED"
    exit 1
fi
echo "CI: OK"
