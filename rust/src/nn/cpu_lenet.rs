//! Pure-Rust LeNet executors — the ATxC ("CPU direct simulation") system of
//! Tables V/VI. Forward and full backward with every multiply routed
//! through a [`MulKernel`]; used by the CPU-path benchmarks and as an
//! end-to-end oracle against the compiled artifacts.

use crate::kernels::MulKernel;
use crate::layers::activations::{relu, relu_backward};
use crate::layers::softmax::cross_entropy_sum_with_grad;
use crate::layers::{amconv2d, amdense};
use crate::tensor::Tensor;
use crate::util::rng::Pcg32;

/// Concatenate tensors into one flat parameter/gradient vector. The order
/// of `parts` is the model's canonical flat layout — `grad_step`,
/// `apply_grads`, `flat_params` and `load_flat` must all agree on it.
fn flatten(parts: &[&Tensor]) -> Vec<f32> {
    let mut out = Vec::with_capacity(parts.iter().map(|t| t.data.len()).sum());
    for t in parts {
        out.extend_from_slice(&t.data);
    }
    out
}

/// Scatter a flat vector back over the same canonical layout, applying
/// `f(param, value)` element-wise (SGD step or plain overwrite).
fn scatter(parts: &mut [&mut Tensor], flat: &[f32], mut f: impl FnMut(&mut f32, f32)) {
    let want: usize = parts.iter().map(|t| t.data.len()).sum();
    assert_eq!(flat.len(), want, "flat vector has {} elements, model has {want}", flat.len());
    let mut off = 0usize;
    for t in parts {
        for (p, &v) in t.data.iter_mut().zip(&flat[off..off + t.data.len()]) {
            f(p, v);
        }
        off += t.data.len();
    }
}

/// LeNet-300-100 parameters.
#[derive(Clone)]
pub struct Lenet300 {
    pub w1: Tensor,
    pub b1: Tensor,
    pub w2: Tensor,
    pub b2: Tensor,
    pub w3: Tensor,
    pub b3: Tensor,
}

impl Lenet300 {
    pub fn init(n_in: usize, classes: usize, seed: u64) -> Lenet300 {
        let he = |shape: &[usize], fan_in: usize, stream: u64| {
            let mut rng = Pcg32::new(seed, stream);
            let std = (2.0 / fan_in as f32).sqrt();
            let n: usize = shape.iter().product();
            Tensor::from_vec(shape, (0..n).map(|_| std * rng.normal()).collect())
        };
        Lenet300 {
            w1: he(&[n_in, 300], n_in, 1),
            b1: Tensor::zeros(&[300]),
            w2: he(&[300, 100], 300, 2),
            b2: Tensor::zeros(&[100]),
            w3: he(&[100, classes], 100, 3),
            b3: Tensor::zeros(&[classes]),
        }
    }

    /// Forward pass; `x` is `[batch, n_in]`.
    pub fn forward(&self, mul: &MulKernel, x: &Tensor) -> Tensor {
        let h1 = relu(&amdense::forward(mul, x, &self.w1, Some(&self.b1)));
        let h2 = relu(&amdense::forward(mul, &h1, &self.w2, Some(&self.b2)));
        amdense::forward(mul, &h2, &self.w3, Some(&self.b3))
    }

    /// Total parameter elements in the canonical flat layout.
    pub fn param_count(&self) -> usize {
        self.flat_order().iter().map(|t| t.data.len()).sum()
    }

    /// Canonical flat layout: `w1 b1 w2 b2 w3 b3`.
    fn flat_order(&self) -> [&Tensor; 6] {
        [&self.w1, &self.b1, &self.w2, &self.b2, &self.w3, &self.b3]
    }

    fn flat_order_mut(&mut self) -> [&mut Tensor; 6] {
        [&mut self.w1, &mut self.b1, &mut self.w2, &mut self.b2, &mut self.w3, &mut self.b3]
    }

    /// Snapshot every parameter into one flat vector (canonical order).
    pub fn flat_params(&self) -> Vec<f32> {
        flatten(&self.flat_order())
    }

    /// Overwrite every parameter from a flat vector (canonical order).
    pub fn load_flat(&mut self, flat: &[f32]) {
        scatter(&mut self.flat_order_mut(), flat, |p, v| *p = v);
    }

    /// Compute-only step for the data-parallel path: forward + backward on
    /// `x` without touching parameters. Returns the shard's loss **sum**,
    /// correct **count**, and the flat gradient (canonical order) with the
    /// loss gradient scaled by `1/divisor` — pass the *effective* batch
    /// size so shard gradients sum exactly to the monolithic gradient.
    /// Taking `&self` is load-bearing: a panic anywhere in here can never
    /// leave a replica with a torn parameter update.
    pub fn grad_step(
        &self,
        mul: &MulKernel,
        x: &Tensor,
        labels: &[u32],
        divisor: usize,
    ) -> (f32, usize, Vec<f32>) {
        // forward, keeping pre-activations for relu backward
        let z1 = amdense::forward(mul, x, &self.w1, Some(&self.b1));
        let h1 = relu(&z1);
        let z2 = amdense::forward(mul, &h1, &self.w2, Some(&self.b2));
        let h2 = relu(&z2);
        let logits = amdense::forward(mul, &h2, &self.w3, Some(&self.b3));
        let (loss_sum, correct, dlogits) = cross_entropy_sum_with_grad(&logits, labels, divisor);
        // backward
        let dw3 = amdense::weight_grad(mul, &h2, &dlogits);
        let db3 = amdense::bias_grad(&dlogits);
        let dh2 = relu_backward(&amdense::input_grad(mul, &dlogits, &self.w3), &z2);
        let dw2 = amdense::weight_grad(mul, &h1, &dh2);
        let db2 = amdense::bias_grad(&dh2);
        let dh1 = relu_backward(&amdense::input_grad(mul, &dh2, &self.w2), &z1);
        let dw1 = amdense::weight_grad(mul, x, &dh1);
        let db1 = amdense::bias_grad(&dh1);
        (loss_sum, correct, flatten(&[&dw1, &db1, &dw2, &db2, &dw3, &db3]))
    }

    /// Plain SGD over a flat gradient: `p -= lr * g` per element.
    pub fn apply_grads(&mut self, flat: &[f32], lr: f32) {
        scatter(&mut self.flat_order_mut(), flat, |p, g| *p -= lr * g);
    }

    /// One SGD training step; returns (loss, accuracy). Exactly
    /// `grad_step` + `apply_grads` — the single-replica path and the
    /// data-parallel path share every float op.
    pub fn train_step(
        &mut self,
        mul: &MulKernel,
        x: &Tensor,
        labels: &[u32],
        lr: f32,
    ) -> (f32, f32) {
        let b = x.shape[0];
        let (loss_sum, correct, grads) = self.grad_step(mul, x, labels, b);
        self.apply_grads(&grads, lr);
        let inv_b = 1.0 / b as f32;
        (loss_sum * inv_b, correct as f32 * inv_b)
    }
}

/// LeNet-5 parameters (28x28x1 input).
#[derive(Clone)]
pub struct Lenet5 {
    pub c1: Tensor, // [5,5,1,6]
    pub c2: Tensor, // [5,5,6,16]
    pub w1: Tensor, // [400,120]
    pub b1: Tensor,
    pub w2: Tensor, // [120,84]
    pub b2: Tensor,
    pub w3: Tensor, // [84,10]
    pub b3: Tensor,
}

impl Lenet5 {
    pub fn init(seed: u64) -> Lenet5 {
        let he = |shape: &[usize], fan_in: usize, stream: u64| {
            let mut rng = Pcg32::new(seed, stream);
            let std = (2.0 / fan_in as f32).sqrt();
            let n: usize = shape.iter().product();
            Tensor::from_vec(shape, (0..n).map(|_| std * rng.normal()).collect())
        };
        Lenet5 {
            c1: he(&[5, 5, 1, 6], 25, 1),
            c2: he(&[5, 5, 6, 16], 150, 2),
            w1: he(&[400, 120], 400, 3),
            b1: Tensor::zeros(&[120]),
            w2: he(&[120, 84], 120, 4),
            b2: Tensor::zeros(&[84]),
            w3: he(&[84, 10], 84, 5),
            b3: Tensor::zeros(&[10]),
        }
    }

    /// Forward; `x` is `[batch, 28, 28, 1]`.
    pub fn forward(&self, mul: &MulKernel, x: &Tensor) -> Tensor {
        use crate::kernels::pool::maxpool2x2;
        let a1 = relu(&amconv2d::forward(mul, x, &self.c1, 1, 2));
        let (p1, _) = maxpool2x2(&a1.data, x.shape[0], 28, 28, 6);
        let p1 = Tensor::from_vec(&[x.shape[0], 14, 14, 6], p1);
        let a2 = relu(&amconv2d::forward(mul, &p1, &self.c2, 1, 0));
        let (p2, _) = maxpool2x2(&a2.data, x.shape[0], 10, 10, 16);
        let p2 = Tensor::from_vec(&[x.shape[0], 400], p2);
        let h1 = relu(&amdense::forward(mul, &p2, &self.w1, Some(&self.b1)));
        let h2 = relu(&amdense::forward(mul, &h1, &self.w2, Some(&self.b2)));
        amdense::forward(mul, &h2, &self.w3, Some(&self.b3))
    }

    /// Total parameter elements in the canonical flat layout.
    pub fn param_count(&self) -> usize {
        self.flat_order().iter().map(|t| t.data.len()).sum()
    }

    /// Canonical flat layout: `c1 c2 w1 b1 w2 b2 w3 b3`.
    fn flat_order(&self) -> [&Tensor; 8] {
        [&self.c1, &self.c2, &self.w1, &self.b1, &self.w2, &self.b2, &self.w3, &self.b3]
    }

    fn flat_order_mut(&mut self) -> [&mut Tensor; 8] {
        [
            &mut self.c1,
            &mut self.c2,
            &mut self.w1,
            &mut self.b1,
            &mut self.w2,
            &mut self.b2,
            &mut self.w3,
            &mut self.b3,
        ]
    }

    /// Snapshot every parameter into one flat vector (canonical order).
    pub fn flat_params(&self) -> Vec<f32> {
        flatten(&self.flat_order())
    }

    /// Overwrite every parameter from a flat vector (canonical order).
    pub fn load_flat(&mut self, flat: &[f32]) {
        scatter(&mut self.flat_order_mut(), flat, |p, v| *p = v);
    }

    /// Compute-only step (see [`Lenet300::grad_step`]): loss sum, correct
    /// count, flat gradient with the loss grad scaled by `1/divisor`.
    pub fn grad_step(
        &self,
        mul: &MulKernel,
        x: &Tensor,
        labels: &[u32],
        divisor: usize,
    ) -> (f32, usize, Vec<f32>) {
        use crate::kernels::pool::{maxpool2x2, maxpool2x2_backward};
        let batch = x.shape[0];
        // forward (cache everything)
        let z1 = amconv2d::forward(mul, x, &self.c1, 1, 2);
        let a1 = relu(&z1);
        let (p1d, arg1) = maxpool2x2(&a1.data, batch, 28, 28, 6);
        let p1 = Tensor::from_vec(&[batch, 14, 14, 6], p1d);
        let z2 = amconv2d::forward(mul, &p1, &self.c2, 1, 0);
        let a2 = relu(&z2);
        let (p2d, arg2) = maxpool2x2(&a2.data, batch, 10, 10, 16);
        let flat = Tensor::from_vec(&[batch, 400], p2d);
        let zf1 = amdense::forward(mul, &flat, &self.w1, Some(&self.b1));
        let h1 = relu(&zf1);
        let zf2 = amdense::forward(mul, &h1, &self.w2, Some(&self.b2));
        let h2 = relu(&zf2);
        let logits = amdense::forward(mul, &h2, &self.w3, Some(&self.b3));
        let (loss_sum, correct, dlogits) = cross_entropy_sum_with_grad(&logits, labels, divisor);
        // dense backward
        let dw3 = amdense::weight_grad(mul, &h2, &dlogits);
        let db3 = amdense::bias_grad(&dlogits);
        let dh2 = relu_backward(&amdense::input_grad(mul, &dlogits, &self.w3), &zf2);
        let dw2 = amdense::weight_grad(mul, &h1, &dh2);
        let db2 = amdense::bias_grad(&dh2);
        let dh1 = relu_backward(&amdense::input_grad(mul, &dh2, &self.w2), &zf1);
        let dw1 = amdense::weight_grad(mul, &flat, &dh1);
        let db1 = amdense::bias_grad(&dh1);
        let dflat = amdense::input_grad(mul, &dh1, &self.w1);
        // conv2 backward through pool2
        let da2 = maxpool2x2_backward(&dflat.data, &arg2, batch * 10 * 10 * 16);
        let dz2 = relu_backward(&Tensor::from_vec(&[batch, 10, 10, 16], da2), &z2);
        let dc2 = amconv2d::weight_grad(mul, &p1, &dz2, &self.c2.shape, 1, 0);
        let dp1 = amconv2d::input_grad(mul, &dz2, &self.c2, &p1.shape, 1, 0);
        // conv1 backward through pool1
        let da1 = maxpool2x2_backward(&dp1.data, &arg1, batch * 28 * 28 * 6);
        let dz1 = relu_backward(&Tensor::from_vec(&[batch, 28, 28, 6], da1), &z1);
        let dc1 = amconv2d::weight_grad(mul, x, &dz1, &self.c1.shape, 1, 2);
        (loss_sum, correct, flatten(&[&dc1, &dc2, &dw1, &db1, &dw2, &db2, &dw3, &db3]))
    }

    /// Plain SGD over a flat gradient: `p -= lr * g` per element.
    pub fn apply_grads(&mut self, flat: &[f32], lr: f32) {
        scatter(&mut self.flat_order_mut(), flat, |p, g| *p -= lr * g);
    }

    /// One SGD step (full backward through convs and pools); exactly
    /// `grad_step` + `apply_grads`.
    pub fn train_step(
        &mut self,
        mul: &MulKernel,
        x: &Tensor,
        labels: &[u32],
        lr: f32,
    ) -> (f32, f32) {
        let b = x.shape[0];
        let (loss_sum, correct, grads) = self.grad_step(mul, x, labels, b);
        self.apply_grads(&grads, lr);
        let inv_b = 1.0 / b as f32;
        (loss_sum * inv_b, correct as f32 * inv_b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{mnist_like, SynthSpec};

    #[test]
    fn lenet300_learns_one_batch() {
        let ds = mnist_like(&SynthSpec { n: 32, ..SynthSpec::mnist_like_default() });
        let x = Tensor::from_vec(&[32, 784], ds.images.clone());
        let mut net = Lenet300::init(784, 10, 7);
        let mul = MulKernel::Native;
        let (l0, _) = net.train_step(&mul, &x, &ds.labels, 0.05);
        let mut last = l0;
        for _ in 0..8 {
            let (l, _) = net.train_step(&mul, &x, &ds.labels, 0.05);
            last = l;
        }
        assert!(last < l0 * 0.7, "loss {l0} -> {last}");
    }

    #[test]
    fn split_step_is_bitwise_train_step_and_flat_roundtrips() {
        // the data-parallel path drives grad_step + apply_grads directly;
        // they must be the same float ops as train_step, and the flat
        // param vector must round-trip exactly
        let mut rng = Pcg32::seeded(9);
        let x = Tensor::from_vec(&[6, 36], (0..6 * 36).map(|_| rng.range(-1.0, 1.0)).collect());
        let labels: Vec<u32> = (0..6).map(|_| rng.below(10)).collect();
        let mul = MulKernel::Native;
        let mut a = Lenet300::init(36, 10, 5);
        let mut b = a.clone();
        let (loss_a, acc_a) = a.train_step(&mul, &x, &labels, 0.05);
        let (loss_sum, correct, grads) = b.grad_step(&mul, &x, &labels, 6);
        assert_eq!(grads.len(), b.param_count());
        b.apply_grads(&grads, 0.05);
        assert_eq!(loss_a.to_bits(), (loss_sum * (1.0 / 6.0)).to_bits());
        assert_eq!(acc_a.to_bits(), (correct as f32 * (1.0 / 6.0)).to_bits());
        let (fa, fb) = (a.flat_params(), b.flat_params());
        for i in 0..fa.len() {
            assert_eq!(fa[i].to_bits(), fb[i].to_bits(), "param {i}");
        }
        // load_flat overwrites a differently-seeded net completely
        let mut c = Lenet300::init(36, 10, 777);
        c.load_flat(&fa);
        assert_eq!(c.flat_params(), fa);
        // lenet5 flat layout is self-consistent too
        let net5 = Lenet5::init(3);
        let flat5 = net5.flat_params();
        assert_eq!(flat5.len(), net5.param_count());
        let mut other5 = Lenet5::init(4);
        other5.load_flat(&flat5);
        assert_eq!(other5.flat_params(), flat5);
    }

    #[test]
    fn lenet5_learns_one_batch_with_approx_mult() {
        use crate::amsim::AmSim;
        use crate::lut::MantissaLut;
        use crate::mult::registry;
        let ds = mnist_like(&SynthSpec { n: 8, ..SynthSpec::mnist_like_default() });
        let x = Tensor::from_vec(&[8, 28, 28, 1], ds.images.clone());
        let model = registry::by_name("afm16").unwrap();
        let lut = MantissaLut::generate(model.as_ref());
        let mul = MulKernel::Lut(AmSim::new(&lut));
        let mut net = Lenet5::init(7);
        let (l0, _) = net.train_step(&mul, &x, &ds.labels, 0.05);
        let mut last = l0;
        for _ in 0..6 {
            let (l, _) = net.train_step(&mul, &x, &ds.labels, 0.05);
            last = l;
        }
        assert!(last < l0, "approx loss did not decrease: {l0} -> {last}");
    }
}
