//! The three IM2COL kernels (paper §VI-D), each available in two forms
//! sharing one set of index computations:
//!
//! * **Implicit panel sources** ([`Im2colForwardSrc`],
//!   [`Im2colWeightGradSrc`], [`Im2colPlgSrc`]) — [`PackA`]
//!   implementations that pack tiled-GEMM panels *directly from the NHWC
//!   tensors*; the cols matrix exists only logically ("implicit GEMM",
//!   the completion of the paper's fusion idea: not even the fused-index
//!   result array is materialized). All three conv GEMMs put the im2col
//!   operand on the `A` side, whose row-major `ih x kw` panel layout is
//!   exactly what the register-blocked micro-kernel drain consumes
//!   ([`crate::kernels::MulBackend::mul_microtile`] reads `MR`
//!   consecutive panel rows with row stride `kw`), so these sources
//!   needed no layout change for the micro-kernel; the `NR`-strip
//!   interleaved `B` panels are produced by the weight/error-side
//!   [`crate::kernels::gemm::SliceB`].
//! * **Materialized functions** ([`im2col_forward`],
//!   [`im2col_weight_grad`], [`im2col_plg`]) — fill the full cols matrix
//!   by packing the whole logical range through the same source; kept as
//!   the oracle / bench comparison partner for the implicit route.
//!
//! The per-element semantics are the paper's:
//!
//! * forward — standard patch extraction;
//! * weight grad — the dilation of `Errors^{l+1}` implied by stride > 1
//!   is **fused** by *skipping* input elements instead of materializing a
//!   dilated array (§VI-B.1);
//! * preceding-layer grad — each element checks whether its position in
//!   the logical `PaddedDilatedErrors^{l+1}` is a dilated/padded (zero)
//!   position and reads the undilated error array otherwise (§VI-B.2).
//! * [`dilate_explicit`] — the naive separate-dilation baseline the paper
//!   argues against; kept for the ablation benchmark.
//!
//! Occupancy: the implicit sources inherit [`PackA::pack_a_occ`]'s
//! pack-then-scan default, so the sparse drain's per-micro-panel bitmaps
//! come for free — including the padding/dilation zeros these sources
//! synthesize, which register as dead exactly like materialized zeros
//! (the occupancy leg of
//! `tests::implicit_sources_pack_identically_to_materialized_slices`).

use super::gemm::PackA;
use super::Conv2dGeom;

/// Implicit forward-im2col source: the logical matrix
/// `cols[b*oh*ow, kh*kw*c]` over an NHWC `input`, packed panel-by-panel
/// with zero padding fused into the indexing.
pub struct Im2colForwardSrc<'a> {
    g: Conv2dGeom,
    input: &'a [f32],
    oh: usize,
    ow: usize,
}

impl<'a> Im2colForwardSrc<'a> {
    pub fn new(g: &Conv2dGeom, input: &'a [f32]) -> Im2colForwardSrc<'a> {
        assert_eq!(input.len(), g.batch * g.in_h * g.in_w * g.in_c);
        Im2colForwardSrc { g: *g, input, oh: g.out_h(), ow: g.out_w() }
    }

    /// Fill `out` with logical row `r`, columns `[k0, k0 + kw)`. Columns
    /// decompose as `(ky, kx, ci)`; each `(ky, kx)` cell is an `in_c` run
    /// that is either a contiguous copy or fused-padding zeros.
    fn fill_row(&self, r: usize, k0: usize, kw: usize, out: &mut [f32]) {
        let g = &self.g;
        let b = r / (self.oh * self.ow);
        let rem = r % (self.oh * self.ow);
        let (oy, ox) = (rem / self.ow, rem % self.ow);
        let in_base = b * g.in_h * g.in_w * g.in_c;
        let mut col = k0;
        let mut o = 0;
        while o < kw {
            let ky = col / (g.k_w * g.in_c);
            let rem = col % (g.k_w * g.in_c);
            let (kx, ci) = (rem / g.in_c, rem % g.in_c);
            let run = (g.in_c - ci).min(kw - o);
            let iy = (oy * g.stride + ky) as isize - g.pad as isize;
            let ix = (ox * g.stride + kx) as isize - g.pad as isize;
            if iy < 0 || iy >= g.in_h as isize || ix < 0 || ix >= g.in_w as isize {
                out[o..o + run].fill(0.0);
            } else {
                let src = in_base + (iy as usize * g.in_w + ix as usize) * g.in_c + ci;
                out[o..o + run].copy_from_slice(&self.input[src..src + run]);
            }
            col += run;
            o += run;
        }
    }
}

impl PackA for Im2colForwardSrc<'_> {
    fn pack_a(&self, i0: usize, ih: usize, k0: usize, kw: usize, out: &mut [f32]) {
        for i in 0..ih {
            self.fill_row(i0 + i, k0, kw, &mut out[i * kw..(i + 1) * kw]);
        }
    }
}

/// Forward im2col: `cols[b*oh*ow, kh*kw*c]`, NHWC input, zero padding.
/// Materializes [`Im2colForwardSrc`]'s full logical matrix.
pub fn im2col_forward(g: &Conv2dGeom, input: &[f32], cols: &mut [f32]) {
    assert_eq!(cols.len(), g.col_rows() * g.col_cols());
    Im2colForwardSrc::new(g, input).pack_a(0, g.col_rows(), 0, g.col_cols(), cols);
}

/// Implicit weight-gradient im2col source with fused dilation (paper
/// §VI-B.1): the logical matrix `cols[kh*kw*c, b*oh*ow]` such that
/// `dW[kh*kw*c, oc] = cols x dY[b*oh*ow, oc]`. The stride-induced
/// dilation of the error map is realized by *reading the activation at
/// strided positions* — no dilated array (and now no cols matrix) is
/// ever built.
pub struct Im2colWeightGradSrc<'a> {
    g: Conv2dGeom,
    activation: &'a [f32],
    oh: usize,
    ow: usize,
}

impl<'a> Im2colWeightGradSrc<'a> {
    pub fn new(g: &Conv2dGeom, activation: &'a [f32]) -> Im2colWeightGradSrc<'a> {
        assert_eq!(activation.len(), g.batch * g.in_h * g.in_w * g.in_c);
        Im2colWeightGradSrc { g: *g, activation, oh: g.out_h(), ow: g.out_w() }
    }

    /// Fill `out` with logical row `r = (ky*kw + kx)*in_c + c`, columns
    /// (output positions) `[q0, q0 + qw)`; `iy` is hoisted per `oy` run.
    fn fill_row(&self, r: usize, q0: usize, qw: usize, out: &mut [f32]) {
        let g = &self.g;
        let ky = r / (g.k_w * g.in_c);
        let rem = r % (g.k_w * g.in_c);
        let (kx, c) = (rem / g.in_c, rem % g.in_c);
        let mut q = q0;
        let mut o = 0;
        while o < qw {
            let b = q / (self.oh * self.ow);
            let rem = q % (self.oh * self.ow);
            let (oy, ox0) = (rem / self.ow, rem % self.ow);
            let run = (self.ow - ox0).min(qw - o);
            // fused dilation: stride positions are *skipped reads* of the
            // activation, exactly the paper's IM2COL_Weight_Kernel
            // element skipping
            let iy = (oy * g.stride + ky) as isize - g.pad as isize;
            if iy < 0 || iy >= g.in_h as isize {
                out[o..o + run].fill(0.0);
            } else {
                let row_base =
                    (b * g.in_h + iy as usize) * g.in_w * g.in_c + c;
                for (t, ox) in (ox0..ox0 + run).enumerate() {
                    let ix = (ox * g.stride + kx) as isize - g.pad as isize;
                    out[o + t] = if ix < 0 || ix >= g.in_w as isize {
                        0.0
                    } else {
                        self.activation[row_base + ix as usize * g.in_c]
                    };
                }
            }
            q += run;
            o += run;
        }
    }
}

impl PackA for Im2colWeightGradSrc<'_> {
    fn pack_a(&self, i0: usize, ih: usize, k0: usize, kw: usize, out: &mut [f32]) {
        for i in 0..ih {
            self.fill_row(i0 + i, k0, kw, &mut out[i * kw..(i + 1) * kw]);
        }
    }
}

/// Weight-gradient im2col with fused dilation (paper §VI-B.1).
/// Materializes [`Im2colWeightGradSrc`]'s full logical matrix.
pub fn im2col_weight_grad(g: &Conv2dGeom, activation: &[f32], cols: &mut [f32]) {
    let q_len = g.batch * g.out_h() * g.out_w();
    assert_eq!(cols.len(), g.col_cols() * q_len);
    Im2colWeightGradSrc::new(g, activation).pack_a(0, g.col_cols(), 0, q_len, cols);
}

/// Implicit preceding-layer-gradient im2col source (paper §VI-B.2 /
/// IM2COL_PLG_Kernel): the logical matrix `cols[b*in_h*in_w, kh*kw*oc]`
/// so that `dX = cols x TransposedReversedW[kh*kw*oc, c]`.
///
/// Logically: pad and dilate `errors[b, oh, ow, oc]` to
/// `PD[b, (oh-1)*s+1 + 2*(kh-1-pad), ...]`, then im2col with stride 1 and
/// a `kh x kw` window. Physically: each element computes its position
/// inside the logical padded-dilated array and either copies a zero
/// (dilated/padded position) or reads the original `errors` — the fused
/// pad+dilate of the paper, now without materializing the cols matrix
/// either.
pub struct Im2colPlgSrc<'a> {
    g: Conv2dGeom,
    errors: &'a [f32],
    oh: usize,
    ow: usize,
    /// full-correlation padding of the dilated map
    pad_h: isize,
    pad_w: isize,
}

impl<'a> Im2colPlgSrc<'a> {
    pub fn new(g: &Conv2dGeom, errors: &'a [f32]) -> Im2colPlgSrc<'a> {
        let (oh, ow) = (g.out_h(), g.out_w());
        assert_eq!(errors.len(), g.batch * oh * ow * g.out_c);
        Im2colPlgSrc {
            g: *g,
            errors,
            oh,
            ow,
            pad_h: g.k_h as isize - 1 - g.pad as isize,
            pad_w: g.k_w as isize - 1 - g.pad as isize,
        }
    }

    /// Fill `out` with logical row `r = (b*in_h + y)*in_w + x`, columns
    /// `[k0, k0 + kw)`; each `(ky, kx)` cell is an `out_c` run that is
    /// either a contiguous error copy or a fused pad/dilate zero.
    fn fill_row(&self, r: usize, k0: usize, kw: usize, out: &mut [f32]) {
        let g = &self.g;
        let b = r / (g.in_h * g.in_w);
        let rem = r % (g.in_h * g.in_w);
        let (y, x) = ((rem / g.in_w) as isize, (rem % g.in_w) as isize);
        let e_base = b * self.oh * self.ow * g.out_c;
        let s = g.stride as isize;
        let mut col = k0;
        let mut o = 0;
        while o < kw {
            let ky = col / (g.k_w * g.out_c);
            let rem = col % (g.k_w * g.out_c);
            let (kx, ch) = (rem / g.out_c, rem % g.out_c);
            let run = (g.out_c - ch).min(kw - o);
            // position inside the logical dilated (stride-spaced) map: a
            // real error element sits at (oy*s, ox*s); everything else is
            // a fused zero
            let dy = y + ky as isize - self.pad_h;
            let dx = x + kx as isize - self.pad_w;
            let valid = dy >= 0
                && dx >= 0
                && dy % s == 0
                && dx % s == 0
                && dy / s < self.oh as isize
                && dx / s < self.ow as isize;
            if valid {
                let src = e_base
                    + ((dy / s) as usize * self.ow + (dx / s) as usize) * g.out_c
                    + ch;
                out[o..o + run].copy_from_slice(&self.errors[src..src + run]);
            } else {
                out[o..o + run].fill(0.0);
            }
            col += run;
            o += run;
        }
    }
}

impl PackA for Im2colPlgSrc<'_> {
    fn pack_a(&self, i0: usize, ih: usize, k0: usize, kw: usize, out: &mut [f32]) {
        for i in 0..ih {
            self.fill_row(i0 + i, k0, kw, &mut out[i * kw..(i + 1) * kw]);
        }
    }
}

/// Preceding-layer-gradient im2col (paper §VI-B.2 / IM2COL_PLG_Kernel).
/// Materializes [`Im2colPlgSrc`]'s full logical matrix.
pub fn im2col_plg(g: &Conv2dGeom, errors: &[f32], cols: &mut [f32]) {
    let rows = g.batch * g.in_h * g.in_w;
    let rlen = g.k_h * g.k_w * g.out_c;
    assert_eq!(cols.len(), rows * rlen);
    Im2colPlgSrc::new(g, errors).pack_a(0, rows, 0, rlen, cols);
}

/// Naive explicit dilation (the baseline the paper's fused approach
/// replaces): insert `stride-1` zeros between error elements. Returns the
/// dilated map of shape `[batch, (oh-1)*s+1, (ow-1)*s+1, oc]`.
pub fn dilate_explicit(g: &Conv2dGeom, errors: &[f32]) -> (Vec<f32>, usize, usize) {
    let (oh, ow) = (g.out_h(), g.out_w());
    assert_eq!(errors.len(), g.batch * oh * ow * g.out_c);
    let dh = (oh - 1) * g.stride + 1;
    let dw = (ow - 1) * g.stride + 1;
    let mut out = vec![0.0f32; g.batch * dh * dw * g.out_c];
    for b in 0..g.batch {
        for oy in 0..oh {
            for ox in 0..ow {
                let src = ((b * oh + oy) * ow + ox) * g.out_c;
                let dst = ((b * dh + oy * g.stride) * dw + ox * g.stride) * g.out_c;
                out[dst..dst + g.out_c].copy_from_slice(&errors[src..src + g.out_c]);
            }
        }
    }
    (out, dh, dw)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn geom(stride: usize, pad: usize) -> Conv2dGeom {
        Conv2dGeom {
            batch: 2,
            in_h: 6,
            in_w: 6,
            in_c: 3,
            k_h: 3,
            k_w: 3,
            out_c: 4,
            stride,
            pad,
        }
    }

    #[test]
    fn forward_identity_kernel() {
        // 1x1 kernel, stride 1, no pad: cols == input
        let g = Conv2dGeom { k_h: 1, k_w: 1, ..geom(1, 0) };
        let n = g.batch * g.in_h * g.in_w * g.in_c;
        let input: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let mut cols = vec![0.0f32; g.col_rows() * g.col_cols()];
        im2col_forward(&g, &input, &mut cols);
        assert_eq!(cols, input);
    }

    #[test]
    fn forward_padding_zeros_at_border() {
        let g = geom(1, 1);
        let n = g.batch * g.in_h * g.in_w * g.in_c;
        let input = vec![1.0f32; n];
        let mut cols = vec![-1.0f32; g.col_rows() * g.col_cols()];
        im2col_forward(&g, &input, &mut cols);
        // first output position (0,0): top-left 3x3 patch has 5 padded
        // positions (first row + first col) of 3 channels each
        let first_patch = &cols[0..g.col_cols()];
        let zeros = first_patch.iter().filter(|&&v| v == 0.0).count();
        assert_eq!(zeros, 5 * 3);
        assert_eq!(first_patch.iter().filter(|&&v| v == 1.0).count(), 4 * 3);
    }

    /// Fused-dilation weight-grad columns must equal the explicit route:
    /// dilate errors, then compute the stride-1 weight-grad columns.
    #[test]
    fn weight_grad_fusion_equals_explicit_dilation() {
        for stride in [1, 2, 3] {
            let g = geom(stride, 1);
            let mut rng = Pcg32::seeded(31);
            let act: Vec<f32> =
                (0..g.batch * g.in_h * g.in_w * g.in_c).map(|_| rng.range(-1.0, 1.0)).collect();
            let (oh, ow) = (g.out_h(), g.out_w());
            let q = g.batch * oh * ow;
            let mut cols = vec![0.0f32; g.col_cols() * q];
            im2col_weight_grad(&g, &act, &mut cols);
            // reference: dW[r, oc] via direct convolution definition
            let errors: Vec<f32> = (0..q * g.out_c).map(|_| rng.range(-1.0, 1.0)).collect();
            // dW from cols x errors
            let mut dw_fused = vec![0.0f32; g.col_cols() * g.out_c];
            for r in 0..g.col_cols() {
                for oc in 0..g.out_c {
                    let mut acc = 0.0;
                    for qq in 0..q {
                        acc += cols[r * q + qq] * errors[qq * g.out_c + oc];
                    }
                    dw_fused[r * g.out_c + oc] = acc;
                }
            }
            // dW from the convolution definition
            let mut dw_ref = vec![0.0f32; g.col_cols() * g.out_c];
            for ky in 0..g.k_h {
                for kx in 0..g.k_w {
                    for c in 0..g.in_c {
                        for oc in 0..g.out_c {
                            let mut acc = 0.0;
                            for b in 0..g.batch {
                                for oy in 0..oh {
                                    for ox in 0..ow {
                                        let iy =
                                            (oy * g.stride + ky) as isize - g.pad as isize;
                                        let ix =
                                            (ox * g.stride + kx) as isize - g.pad as isize;
                                        if iy < 0
                                            || ix < 0
                                            || iy >= g.in_h as isize
                                            || ix >= g.in_w as isize
                                        {
                                            continue;
                                        }
                                        let a = act[((b * g.in_h + iy as usize) * g.in_w
                                            + ix as usize)
                                            * g.in_c
                                            + c];
                                        let e = errors
                                            [((b * oh + oy) * ow + ox) * g.out_c + oc];
                                        acc += a * e;
                                    }
                                }
                            }
                            dw_ref[((ky * g.k_w + kx) * g.in_c + c) * g.out_c + oc] = acc;
                        }
                    }
                }
            }
            for i in 0..dw_ref.len() {
                assert!(
                    (dw_fused[i] - dw_ref[i]).abs() < 1e-4,
                    "stride {stride} idx {i}: {} vs {}",
                    dw_fused[i],
                    dw_ref[i]
                );
            }
        }
    }

    #[test]
    fn explicit_dilation_shape_and_content() {
        let g = geom(2, 0);
        let (oh, ow) = (g.out_h(), g.out_w());
        let errors: Vec<f32> = (0..g.batch * oh * ow * g.out_c).map(|i| i as f32 + 1.0).collect();
        let (d, dh, dw) = dilate_explicit(&g, &errors);
        assert_eq!((dh, dw), ((oh - 1) * 2 + 1, (ow - 1) * 2 + 1));
        // non-zero exactly at even positions
        for b in 0..g.batch {
            for y in 0..dh {
                for x in 0..dw {
                    let v = d[((b * dh + y) * dw + x) * g.out_c];
                    if y % 2 == 0 && x % 2 == 0 {
                        assert_ne!(v, 0.0);
                    } else {
                        assert_eq!(v, 0.0);
                    }
                }
            }
        }
    }

    /// Every implicit source must pack any panel window with exactly the
    /// values a `SliceA` over the materialized cols matrix packs — the
    /// foundation of the implicit-GEMM bit-identity claim.
    #[test]
    fn implicit_sources_pack_identically_to_materialized_slices() {
        use crate::kernels::gemm::SliceA;
        use crate::util::rng::Pcg32;
        for (stride, pad) in [(1, 0), (1, 1), (2, 1), (2, 0), (3, 1)] {
            let g = Conv2dGeom { in_h: 7, in_w: 9, ..geom(stride, pad) };
            let mut rng = Pcg32::seeded(33 + stride as u64);
            let input: Vec<f32> =
                (0..g.batch * g.in_h * g.in_w * g.in_c).map(|_| rng.range(-1.0, 1.0)).collect();
            let errors: Vec<f32> = (0..g.batch * g.out_h() * g.out_w() * g.out_c)
                .map(|_| rng.range(-1.0, 1.0))
                .collect();
            let q_len = g.batch * g.out_h() * g.out_w();
            let plg_rows = g.batch * g.in_h * g.in_w;
            let plg_rlen = g.k_h * g.k_w * g.out_c;

            let mut fwd = vec![0.0f32; g.col_rows() * g.col_cols()];
            im2col_forward(&g, &input, &mut fwd);
            let mut wg = vec![0.0f32; g.col_cols() * q_len];
            im2col_weight_grad(&g, &input, &mut wg);
            let mut plg = vec![0.0f32; plg_rows * plg_rlen];
            im2col_plg(&g, &errors, &mut plg);

            let fwd_src = Im2colForwardSrc::new(&g, &input);
            let wg_src = Im2colWeightGradSrc::new(&g, &input);
            let plg_src = Im2colPlgSrc::new(&g, &errors);
            let cases: [(&dyn PackA, &dyn PackA, usize, usize, &str); 3] = [
                (
                    &fwd_src,
                    &SliceA { data: &fwd, k: g.col_cols() },
                    g.col_rows(),
                    g.col_cols(),
                    "forward",
                ),
                (&wg_src, &SliceA { data: &wg, k: q_len }, g.col_cols(), q_len, "weight_grad"),
                (&plg_src, &SliceA { data: &plg, k: plg_rlen }, plg_rows, plg_rlen, "plg"),
            ];
            for (implicit, slice, m, k, what) in cases {
                // windows chosen to straddle in_c/out_c runs, row starts,
                // and the matrix edges
                for &(i0, ih, k0, kw) in &[
                    (0usize, m, 0usize, k),
                    (0, 1.min(m), 0, 1.min(k)),
                    (m / 3, (m - m / 3).min(5), k / 2, k - k / 2),
                    (m.saturating_sub(2), 2.min(m), 1.min(k - 1), (k - 1).max(1).min(3)),
                ] {
                    if ih == 0 || kw == 0 {
                        continue;
                    }
                    let mut got = vec![-7.0f32; ih * kw];
                    let mut want = vec![7.0f32; ih * kw];
                    implicit.pack_a(i0, ih, k0, kw, &mut got);
                    slice.pack_a(i0, ih, k0, kw, &mut want);
                    for i in 0..got.len() {
                        assert_eq!(
                            got[i].to_bits(),
                            want[i].to_bits(),
                            "{what} s{stride}p{pad} window ({i0},{ih},{k0},{kw}) idx {i}"
                        );
                    }
                    // occupancy (the sparse-drain contract): the implicit
                    // source's pack_a_occ default must emit the exact
                    // bitmap the materialized slice emits — the padding
                    // zeros the im2col sources synthesize count as dead
                    // panels the same way materialized zeros do
                    for mr in [1usize, 2, 4] {
                        let mut occ_got = crate::kernels::Occupancy::default();
                        let mut occ_want = crate::kernels::Occupancy::default();
                        implicit.pack_a_occ(i0, ih, k0, kw, mr, &mut got, &mut occ_got);
                        slice.pack_a_occ(i0, ih, k0, kw, mr, &mut want, &mut occ_want);
                        assert_eq!(occ_got.panels(), occ_want.panels());
                        for gi in 0..occ_got.panels() {
                            assert_eq!(
                                occ_got.get(gi),
                                occ_want.get(gi),
                                "{what} s{stride}p{pad} mr={mr} window \
                                 ({i0},{ih},{k0},{kw}) group {gi}"
                            );
                        }
                    }
                }
            }
        }
    }

    /// PLG columns must reproduce the logical pad+dilate+im2col composition.
    #[test]
    fn plg_fusion_equals_explicit_composition() {
        for (stride, pad) in [(1, 0), (1, 1), (2, 1), (2, 0), (3, 1), (3, 0)] {
            let g = geom(stride, pad);
            let (oh, ow) = (g.out_h(), g.out_w());
            let mut rng = Pcg32::seeded(32);
            let errors: Vec<f32> =
                (0..g.batch * oh * ow * g.out_c).map(|_| rng.range(-1.0, 1.0)).collect();
            let rows = g.batch * g.in_h * g.in_w;
            let rlen = g.k_h * g.k_w * g.out_c;
            let mut cols = vec![0.0f32; rows * rlen];
            im2col_plg(&g, &errors, &mut cols);

            // explicit: dilate, add the asymmetric output padding
            // ((in + 2p - k) % s extra zero rows/cols at bottom-right, the
            // standard conv-transpose correction), then pad, then stride-1
            // im2col
            let (d, dh, dw) = dilate_explicit(&g, &errors);
            let opad_h = (g.in_h + 2 * g.pad - g.k_h) % g.stride;
            let opad_w = (g.in_w + 2 * g.pad - g.k_w) % g.stride;
            let (eh, ew) = (dh + opad_h, dw + opad_w);
            let mut d_ext = vec![0.0f32; g.batch * eh * ew * g.out_c];
            for b in 0..g.batch {
                for y in 0..dh {
                    for x in 0..dw {
                        for ch in 0..g.out_c {
                            d_ext[((b * eh + y) * ew + x) * g.out_c + ch] =
                                d[((b * dh + y) * dw + x) * g.out_c + ch];
                        }
                    }
                }
            }
            let gd = Conv2dGeom {
                batch: g.batch,
                in_h: eh,
                in_w: ew,
                in_c: g.out_c,
                k_h: g.k_h,
                k_w: g.k_w,
                out_c: 1,
                stride: 1,
                pad: (g.k_h as isize - 1 - g.pad as isize) as usize,
            };
            assert_eq!((gd.out_h(), gd.out_w()), (g.in_h, g.in_w), "stride {stride} pad {pad}");
            let mut cols_ref = vec![0.0f32; gd.col_rows() * gd.col_cols()];
            im2col_forward(&gd, &d_ext, &mut cols_ref);
            assert_eq!(cols.len(), cols_ref.len());
            for i in 0..cols.len() {
                assert_eq!(cols[i], cols_ref[i], "stride {stride} pad {pad} idx {i}");
            }
        }
    }
}
