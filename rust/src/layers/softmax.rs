//! Softmax + cross-entropy head (exact). Combined forward/backward because
//! the fused gradient `softmax(x) - onehot(y)` is what every framework
//! implements.

use crate::tensor::Tensor;

/// Row-wise softmax of logits `[batch, classes]`, numerically stabilized.
pub fn softmax(logits: &Tensor) -> Tensor {
    assert_eq!(logits.rank(), 2);
    let (b, c) = (logits.shape[0], logits.shape[1]);
    let mut out = Tensor::zeros(&[b, c]);
    for r in 0..b {
        let row = &logits.data[r * c..(r + 1) * c];
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for (o, &v) in out.data[r * c..(r + 1) * c].iter_mut().zip(row) {
            *o = (v - max).exp();
            sum += *o;
        }
        for o in &mut out.data[r * c..(r + 1) * c] {
            *o /= sum;
        }
    }
    out
}

/// Mean cross-entropy loss of logits against integer labels; returns
/// `(loss, accuracy, dlogits)`.
pub fn cross_entropy_with_grad(logits: &Tensor, labels: &[u32]) -> (f32, f32, Tensor) {
    let b = logits.shape[0];
    let (loss_sum, correct, grad) = cross_entropy_sum_with_grad(logits, labels, b);
    let inv_b = 1.0 / b as f32;
    (loss_sum * inv_b, correct as f32 * inv_b, grad)
}

/// Un-averaged cross-entropy head for data-parallel shards: returns the
/// per-batch loss **sum**, the exact correct **count**, and `dlogits`
/// scaled by `1/divisor` instead of `1/batch`. With `divisor` set to the
/// *effective* batch size, a shard of a larger minibatch contributes
/// exactly the gradient rows it would have contributed inside the
/// monolithic batch (the softmax and per-row grads never mix rows), which
/// is what makes the fixed-order shard reduction in
/// `coordinator::data_parallel` bit-identical to single-worker training.
/// Sums and counts (rather than means) stay exactly reducible across
/// shards. [`cross_entropy_with_grad`] is this with `divisor = batch`.
pub fn cross_entropy_sum_with_grad(
    logits: &Tensor,
    labels: &[u32],
    divisor: usize,
) -> (f32, usize, Tensor) {
    let (b, c) = (logits.shape[0], logits.shape[1]);
    assert_eq!(labels.len(), b);
    assert!(divisor > 0, "divisor must be positive");
    let probs = softmax(logits);
    let mut loss = 0.0f32;
    let mut correct = 0usize;
    let mut grad = probs.clone();
    for r in 0..b {
        let y = labels[r] as usize;
        assert!(y < c, "label {y} out of range");
        let p = probs.data[r * c + y].max(1e-12);
        loss -= p.ln();
        grad.data[r * c + y] -= 1.0;
        // shared NaN-tolerant first-max argmax: a diverged run (NaN
        // logits -> NaN probs) scores the row wrong instead of panicking,
        // and ties agree with TF (first max, not last)
        let row = &probs.data[r * c..(r + 1) * c];
        if crate::nn::metrics::argmax(row) == y {
            correct += 1;
        }
    }
    let inv = 1.0 / divisor as f32;
    for g in &mut grad.data {
        *g *= inv;
    }
    (loss, correct, grad)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_rows_sum_to_one() {
        let logits = Tensor::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0]);
        let p = softmax(&logits);
        for r in 0..2 {
            let s: f32 = p.data[r * 3..(r + 1) * 3].iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
        assert!(p.data[2] > p.data[1] && p.data[1] > p.data[0]);
    }

    #[test]
    fn softmax_is_shift_invariant() {
        let a = Tensor::from_vec(&[1, 3], vec![1.0, 2.0, 3.0]);
        let b = Tensor::from_vec(&[1, 3], vec![101.0, 102.0, 103.0]);
        assert!(softmax(&a).max_abs_diff(&softmax(&b)) < 1e-6);
    }

    #[test]
    fn ce_loss_and_grad() {
        let logits = Tensor::from_vec(&[1, 2], vec![0.0, 0.0]);
        let (loss, acc, grad) = cross_entropy_with_grad(&logits, &[1]);
        assert!((loss - (2.0f32).ln()).abs() < 1e-6);
        // argmax tie resolves to the FIRST max (TF semantics): label 1
        // does not win against the tied index 0
        assert_eq!(acc, 0.0);
        let (_, acc0, _) = cross_entropy_with_grad(&logits, &[0]);
        assert_eq!(acc0, 1.0);
        assert!((grad.data[0] - 0.5).abs() < 1e-6);
        assert!((grad.data[1] + 0.5).abs() < 1e-6);
    }

    #[test]
    fn nan_logits_do_not_panic_training_metrics() {
        // a diverged batch: row 0 all-NaN, row 1 healthy. The NaN row's
        // probability hits the 1e-12 floor (max() drops NaN), so the
        // loss stays defined; the accuracy accounting must too — the old
        // partial_cmp().unwrap() argmax panicked here.
        let logits = Tensor::from_vec(&[2, 2], vec![f32::NAN, f32::NAN, 0.0, 9.0]);
        let (loss, acc, grad) = cross_entropy_with_grad(&logits, &[1, 1]);
        assert!(loss > 10.0, "NaN row is scored at the probability floor, loss {loss}");
        assert_eq!(acc, 0.5, "NaN row scores wrong; healthy row still scores");
        assert_eq!(grad.shape, vec![2, 2]);
        assert!(grad.data[3].is_finite(), "healthy row's gradient stays usable");
    }

    #[test]
    fn sum_variant_shards_reassemble_the_monolithic_batch_exactly() {
        // four rows scored monolithically vs as two 2-row shards with the
        // effective-batch divisor: every dlogits row, the loss sum, and
        // the correct count must come out bit-identical (the invariant
        // the data-parallel reduction is built on)
        let logits =
            Tensor::from_vec(&[4, 3], vec![0.5, -0.2, 0.1, 1.0, 0.0, -1.0, -0.3, 0.7, 0.2, 2.0,
                                           -2.0, 0.5]);
        let labels = [2u32, 0, 1, 0];
        let (full_sum, full_correct, full_grad) =
            cross_entropy_sum_with_grad(&logits, &labels, 4);
        let lo = Tensor::from_vec(&[2, 3], logits.data[..6].to_vec());
        let hi = Tensor::from_vec(&[2, 3], logits.data[6..].to_vec());
        let (s0, c0, g0) = cross_entropy_sum_with_grad(&lo, &labels[..2], 4);
        let (s1, c1, g1) = cross_entropy_sum_with_grad(&hi, &labels[2..], 4);
        // loss sums re-associate ((a+b)+(c+d) vs (((a+b)+c)+d), so only the
        // value is close — bit-identity of the *curve* comes from the DP
        // layer fixing one leaf decomposition, not from re-association
        assert!((s0 + s1 - full_sum).abs() <= full_sum.abs() * 1e-6);
        assert_eq!(c0 + c1, full_correct);
        for (i, g) in g0.data.iter().chain(&g1.data).enumerate() {
            assert_eq!(g.to_bits(), full_grad.data[i].to_bits(), "dlogits[{i}]");
        }
        // and the mean head is exactly the sum head divided once
        let (loss, acc, grad) = cross_entropy_with_grad(&logits, &labels);
        assert_eq!(loss.to_bits(), (full_sum * 0.25).to_bits());
        assert_eq!(acc.to_bits(), (full_correct as f32 * 0.25).to_bits());
        assert_eq!(grad.data[0].to_bits(), full_grad.data[0].to_bits());
    }

    #[test]
    fn grad_matches_finite_difference() {
        let logits = Tensor::from_vec(&[2, 3], vec![0.5, -0.2, 0.1, 1.0, 0.0, -1.0]);
        let labels = [2u32, 0u32];
        let (_, _, grad) = cross_entropy_with_grad(&logits, &labels);
        let eps = 1e-3;
        for i in 0..6 {
            let mut lp = logits.clone();
            lp.data[i] += eps;
            let mut lm = logits.clone();
            lm.data[i] -= eps;
            let (fp, _, _) = cross_entropy_with_grad(&lp, &labels);
            let (fm, _, _) = cross_entropy_with_grad(&lm, &labels);
            let num = (fp - fm) / (2.0 * eps);
            assert!((num - grad.data[i]).abs() < 1e-3, "idx {i}: {num} vs {}", grad.data[i]);
        }
    }
}
