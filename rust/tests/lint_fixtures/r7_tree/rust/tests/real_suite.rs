// Planted R7 fixture: a suite file with no [[test]] registration.
#[test]
fn exists() {}
