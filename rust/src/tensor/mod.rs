//! Minimal dense f32 tensor used by the CPU kernel/layer substrate (the
//! ATxC path of the paper's Tables V/VI and the numeric oracle for the
//! compiled artifacts).
//!
//! Layout is row-major over an arbitrary-rank shape; images use NHWC
//! (batch, height, width, channels), matching the L2 JAX models.

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn filled(shape: &[usize], v: f32) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![v; n] }
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Row-major strides.
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1; self.shape.len()];
        for i in (0..self.shape.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.shape[i + 1];
        }
        s
    }

    /// Reshape without copying; total size must match.
    pub fn reshape(mut self, shape: &[usize]) -> Tensor {
        assert_eq!(self.len(), shape.iter().product::<usize>(), "reshape size mismatch");
        self.shape = shape.to_vec();
        self
    }

    /// 4-D accessor (NHWC).
    #[inline]
    pub fn at4(&self, n: usize, h: usize, w: usize, c: usize) -> f32 {
        debug_assert_eq!(self.rank(), 4);
        let (sh, sw, sc) = (self.shape[1], self.shape[2], self.shape[3]);
        self.data[((n * sh + h) * sw + w) * sc + c]
    }

    /// 2-D accessor.
    #[inline]
    pub fn at2(&self, r: usize, c: usize) -> f32 {
        debug_assert_eq!(self.rank(), 2);
        self.data[r * self.shape[1] + c]
    }

    /// Maximum absolute difference against another tensor of the same shape.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Apply `f` elementwise in place.
    pub fn map_inplace<F: Fn(f32) -> f32>(&mut self, f: F) {
        for v in &mut self.data {
            *v = f(*v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_strides() {
        let t = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.len(), 24);
        assert_eq!(t.strides(), vec![12, 4, 1]);
    }

    #[test]
    fn accessors() {
        let t = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(t.at2(0, 1), 2.0);
        assert_eq!(t.at2(1, 0), 3.0);
        let t4 = Tensor::from_vec(&[1, 2, 2, 1], vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(t4.at4(0, 1, 0, 0), 3.0);
    }

    #[test]
    #[should_panic(expected = "reshape size mismatch")]
    fn reshape_checks_size() {
        Tensor::zeros(&[2, 3]).reshape(&[7]);
    }

    #[test]
    fn max_abs_diff_works() {
        let a = Tensor::from_vec(&[2], vec![1.0, 2.0]);
        let b = Tensor::from_vec(&[2], vec![1.5, 2.0]);
        assert_eq!(a.max_abs_diff(&b), 0.5);
    }
}
