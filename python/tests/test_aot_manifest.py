"""Artifact manifest consistency: every artifact file exists, signatures
are well-formed, and the Rust-side contract (roles, dtypes, ordering) is
honored. Skipped when artifacts/ has not been built."""

import json
import os

import pytest

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART_DIR, "manifest.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="artifacts not built")


@pytest.fixture(scope="module")
def manifest():
    with open(MANIFEST) as f:
        return json.load(f)


def test_every_artifact_file_exists(manifest):
    for art in manifest["artifacts"]:
        path = os.path.join(ART_DIR, art["file"])
        assert os.path.exists(path), art["name"]
        assert os.path.getsize(path) > 100, art["name"]


def test_roles_and_dtypes_valid(manifest):
    roles = {"param", "velocity", "input", "label", "lut", "hyper", "metric",
             "logits"}
    dtypes = {"f32", "i32", "u32"}
    for art in manifest["artifacts"]:
        for t in art["inputs"] + art["outputs"]:
            assert t["role"] in roles, (art["name"], t)
            assert t["dtype"] in dtypes, (art["name"], t)
            assert all(isinstance(d, int) and d > 0 for d in t["shape"]) or \
                t["shape"] == [], (art["name"], t)


def test_train_signature_convention(manifest):
    """Inputs: params, velocities, x, y, [lut], lr; outputs: params,
    velocities, loss, acc — the order the Rust trainer assumes."""
    for art in manifest["artifacts"]:
        if art["phase"] != "train":
            continue
        roles = [t["role"] for t in art["inputs"]]
        n_params = roles.count("param")
        assert roles[:n_params] == ["param"] * n_params, art["name"]
        assert roles[n_params:2 * n_params] == ["velocity"] * n_params, art["name"]
        rest = roles[2 * n_params:]
        assert rest[0] == "input" and rest[1] == "label", art["name"]
        assert rest[-1] == "hyper", art["name"]
        if art["mode"] == "lut":
            assert "lut" in rest, art["name"]
        else:
            assert "lut" not in rest, art["name"]
        out_roles = [t["role"] for t in art["outputs"]]
        assert out_roles[-2:] == ["metric", "metric"], art["name"]
        assert out_roles[:n_params] == ["param"] * n_params, art["name"]

        # params and velocities pair up shape-wise and round-trip to outputs
        for i in range(n_params):
            assert art["inputs"][i]["shape"] == art["inputs"][n_params + i]["shape"]
            assert art["inputs"][i]["shape"] == art["outputs"][i]["shape"]


def test_params_carry_init_metadata(manifest):
    for art in manifest["artifacts"]:
        for t in art["inputs"]:
            if t["role"] == "param":
                assert t.get("init") in ("he_normal", "zeros", "ones"), \
                    (art["name"], t["name"])
                if t["init"] == "he_normal":
                    assert t.get("fan_in", 0) > 0, (art["name"], t["name"])


def test_all_modes_present_per_model(manifest):
    models = {a["model"] for a in manifest["artifacts"] if a["phase"] == "train"}
    for model in models:
        modes = {a["mode"] for a in manifest["artifacts"]
                 if a["model"] == model and a["phase"] == "train"}
        assert modes == {"tf", "custom", "lut", "direct:afm32"}, (model, modes)


def test_lut_files_exist_for_tabulatable_mults(manifest):
    from compile import mults
    lut_dir = os.path.join(ART_DIR, "luts")
    for name in mults.LUT_ABLE:
        path = os.path.join(lut_dir, f"{name}.lut")
        assert os.path.exists(path), name
        m = mults.by_name(name)
        expected = 16 + len(name) + 4 * (1 << (2 * m.m)) + 4
        assert os.path.getsize(path) == expected, name
