//! Planted R6 violations: a gated block with no scalar fallthrough and
//! a gated fn with no `#[cfg(not(target_arch …))]` sibling.

pub fn caller(x: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    {
        x[0] += 1.0;
    }
}

#[cfg(target_arch = "x86_64")]
pub fn fast_only(x: f32) -> f32 {
    x + 1.0
}
