"""Pure-jnp oracle for the Pallas kernels.

``gemm_ref``/``matvec_ref`` materialize the full (M, K, N) elementwise
approximate-product tensor and reduce it — trivially correct, memory-hungry,
test-only. The Pallas kernels must match these closely (identical multiply
semantics and FP32 accumulation; only the reduction order differs, so the
pytest tolerance is a few ULPs of the accumulated magnitude).
"""

from __future__ import annotations

import jax.numpy as jnp

from . import bitmath


def elementwise_mul(a, b, mode: str, lut=None, m: int = 7):
    """Dispatch one elementwise multiply batch by mode:
    ``native`` | ``lut`` | ``direct:<mult>``."""
    if mode == "native":
        return a * b
    if mode == "lut":
        assert lut is not None
        return bitmath.amsim_mul(a, b, lut, m)
    if mode.startswith("direct:"):
        return bitmath.direct_mul(a, b, mode.split(":", 1)[1])
    raise ValueError(f"unknown mode {mode!r}")


def gemm_ref(a, b, mode: str, lut=None, m: int = 7):
    """``c[i, j] = sum_k mul(a[i, k], b[k, j])`` with FP32 accumulation."""
    prod = elementwise_mul(a[:, :, None], b[None, :, :], mode, lut, m)
    return jnp.sum(prod, axis=1, dtype=jnp.float32)


def matvec_ref(w, x, mode: str, lut=None, m: int = 7):
    """``y[o] = sum_i mul(w[o, i], x[i])``."""
    prod = elementwise_mul(w, x[None, :], mode, lut, m)
    return jnp.sum(prod, axis=1, dtype=jnp.float32)
